//! The lab-bench side of chip-in-the-loop training: serve hardware
//! devices over TCP.
//!
//! The seed implementation handled one session at a time — one chip, one
//! lab bench.  The fleet version serves a whole [`DevicePool`]: one accept
//! loop, one thread per client session, and a pool lease held for the
//! session's lifetime (the protocol is stateful — `LoadBatch` … `Cost`
//! sequences must hit the same device).  A client that connects while
//! every device is leased out waits inside the lease, bounded by
//! [`ServeOptions::lease_timeout`]; on timeout its first request is
//! answered with a clean protocol error instead of a hang.
//!
//! Plain `std::net` blocking I/O (this offline build has no async
//! runtime; the protocol is strictly request/response so blocking I/O is
//! exact).
//!
//! `Stats = 0x0D` is the one stateless exception: it is answered from
//! the process-global [`crate::obs`] registry *before* (and without)
//! taking a device lease, so a metrics poller (`mgd top`) neither
//! consumes hardware nor waits behind a training session.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use super::protocol as p;
use super::HardwareDevice;
use crate::fleet::pool::DevicePool;
use crate::fleet::telemetry::{Event, Telemetry};

/// Pooled-server knobs.
pub struct ServeOptions {
    /// Stop accepting after this many sessions (in-flight sessions still
    /// complete before return).  `None` = serve forever.
    pub max_sessions: Option<usize>,
    /// How long a session waits for a free device before failing.
    pub lease_timeout: Duration,
    /// Event stream for session lifecycle.
    pub telemetry: Arc<Telemetry>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_sessions: None,
            lease_timeout: Duration::from_secs(30),
            telemetry: Telemetry::null(),
        }
    }
}

/// Serve a single `device` on `addr` (compatibility wrapper: a one-device
/// pool).
///
/// `max_sessions`: if `Some(n)`, return after `n` client sessions have
/// completed (used by tests and the chip-in-the-loop example).
pub fn serve(
    device: Box<dyn HardwareDevice>,
    addr: &str,
    max_sessions: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(device, listener, max_sessions)
}

/// Serve a single device on an already-bound listener (lets callers bind
/// port 0 and learn the real address before serving).
///
/// Matches the seed's serial-server semantics: a queued client waits for
/// the device as long as it takes (effectively no lease timeout), exactly
/// as it used to wait in the accept backlog.
pub fn serve_on(
    device: Box<dyn HardwareDevice>,
    listener: TcpListener,
    max_sessions: Option<usize>,
) -> Result<()> {
    let pool = DevicePool::new(vec![device]);
    // ~10 years; Duration::MAX risks platform-specific saturation quirks
    // inside Condvar::wait_timeout.
    let effectively_forever = Duration::from_secs(315_360_000);
    serve_pool(
        pool,
        listener,
        ServeOptions { max_sessions, lease_timeout: effectively_forever, ..Default::default() },
    )
}

/// Serve a whole device pool: concurrent sessions, each holding one
/// leased device for its lifetime.
///
/// Trust model: lab-bench instrument on a trusted network (same as the
/// seed's serial server).  A connected-but-silent client parks its
/// session thread in a blocking read, exactly as it parked the whole
/// server before; threads are reaped as sessions end, but a hostile
/// flood of idle connections is out of scope here — front with a real
/// proxy if the listener ever faces one.
pub fn serve_pool(
    pool: Arc<DevicePool>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    eprintln!(
        "[device-server] pool of {} device(s) listening on {}",
        pool.size(),
        listener.local_addr()?
    );
    let mut handles = Vec::new();
    let mut accepted = 0usize;
    // On an accept error, fall through to the join below before
    // returning: callers sharing the pool must see every session lease
    // released once serve_pool returns.
    let mut accept_err: Option<anyhow::Error> = None;
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(stream) => stream,
            Err(e) => {
                accept_err = Some(e.into());
                break;
            }
        };
        accepted += 1;
        let session = accepted as u64;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "unknown".to_string());
        eprintln!("[device-server] session {session} from {peer}");
        opts.telemetry.emit(Event::SessionOpened { session, peer });
        let pool = pool.clone();
        let telemetry = opts.telemetry.clone();
        let lease_timeout = opts.lease_timeout;
        let handle = std::thread::Builder::new()
            .name(format!("mgd-session-{session}"))
            .spawn(move || {
                let mut requests = 0u64;
                match handle_session(stream, &pool, lease_timeout, &mut requests) {
                    Ok(()) => telemetry.emit(Event::SessionClosed {
                        session,
                        requests,
                        ok: true,
                        error: None,
                    }),
                    Err(e) => {
                        eprintln!("[device-server] session {session} ended: {e:#}");
                        telemetry.emit(Event::SessionClosed {
                            session,
                            requests,
                            ok: false,
                            error: Some(format!("{e:#}")),
                        });
                    }
                }
            })
            .expect("spawning device-server session thread");
        handles.push(handle);
        // Reap finished sessions so a serve-forever server does not grow an
        // unbounded handle list (dropping a finished handle just detaches).
        handles.retain(|h| !h.is_finished());
        if let Some(max) = opts.max_sessions {
            if accepted >= max {
                break;
            }
        }
    }
    for handle in handles {
        let _ = handle.join();
    }
    match accept_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// One client session over a pool lease.  Counts served requests into
/// `requests` (kept accurate on the error path for telemetry).
fn handle_session(
    stream: TcpStream,
    pool: &Arc<DevicePool>,
    lease_timeout: Duration,
    requests: &mut u64,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    // Stats (and a bare Bye) are answered before — and without — a
    // device lease: a metrics poller must never consume hardware or wait
    // behind a training session.  The first stateful request below
    // triggers the lease for the rest of the session.
    let (first_op, first_payload) = loop {
        let (op, payload) = match p::read_request(&mut reader) {
            Ok(req) => req,
            Err(e) => {
                // Hangup before any device work (a pure Stats poller
                // closing without Bye lands here) — or a live connection
                // that sent garbage; tell the latter why before closing.
                let _ = p::write_err(&mut writer, &format!("{e:#}"));
                return Ok(());
            }
        };
        match op {
            p::Op::Stats => {
                *requests += 1;
                p::write_ok(&mut writer, &stats_reply())?;
            }
            p::Op::Bye => {
                *requests += 1;
                p::write_ok(&mut writer, &[])?;
                return Ok(());
            }
            other => break (other, payload),
        }
    };
    // Lease for the rest of the session: the protocol is stateful, so
    // every device request of a session must land on the same device.
    let mut lease = match pool.lease(lease_timeout) {
        Ok(lease) => lease,
        Err(e) => {
            // Answer the client's pending first request (Hello, for
            // RemoteDevice) with the reason before hanging up.
            let _ = p::write_err(&mut writer, &format!("{e:#}"));
            return Err(e);
        }
    };
    let mut next = Some((first_op, first_payload));
    loop {
        let (op, payload) = match next.take() {
            Some(req) => req,
            None => match p::read_request(&mut reader) {
                Ok(req) => req,
                Err(e) => {
                    // Usually the client hung up without Bye — fine.  If
                    // the connection is actually alive (e.g. an oversized
                    // frame tripped MAX_FRAME_BYTES), tell it why before
                    // closing instead of a silent EOF; a real hangup
                    // ignores this.
                    let _ = p::write_err(&mut writer, &format!("{e:#}"));
                    return Ok(());
                }
            },
        };
        *requests += 1;
        match handle_request(lease.device(), op, &payload) {
            Ok(Some(reply)) => p::write_ok(&mut writer, &reply)?,
            Ok(None) => {
                p::write_ok(&mut writer, &[])?;
                return Ok(()); // Bye
            }
            Err(e) => p::write_err(&mut writer, &format!("{e:#}"))?,
        }
    }
}

/// Render the `Stats` reply payload: the process-global [`crate::obs`]
/// registry as one JSON document.
fn stats_reply() -> Vec<u8> {
    crate::obs::snapshot().to_json().dump().into_bytes()
}

/// Dispatch one request. `Ok(None)` signals session end (Bye).
fn handle_request(
    dev: &mut dyn HardwareDevice,
    op: p::Op,
    payload: &[u8],
) -> Result<Option<Vec<u8>>> {
    let mut pos = 0usize;
    let reply = match op {
        p::Op::Hello => {
            let mut out = Vec::with_capacity(16);
            p::put_u32(&mut out, dev.n_params() as u32);
            p::put_u32(&mut out, dev.batch_size() as u32);
            p::put_u32(&mut out, dev.input_len() as u32);
            p::put_u32(&mut out, dev.n_outputs() as u32);
            out
        }
        p::Op::SetParams => {
            let theta = p::get_array(payload, &mut pos)?;
            dev.set_params(&theta)?;
            Vec::new()
        }
        p::Op::GetParams => {
            let theta = dev.get_params()?;
            let mut out = Vec::with_capacity(4 + 4 * theta.len());
            p::put_array(&mut out, &theta);
            out
        }
        p::Op::ApplyUpdate => {
            let delta = p::get_array(payload, &mut pos)?;
            dev.apply_update(&delta)?;
            Vec::new()
        }
        p::Op::LoadBatch => {
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            dev.load_batch(&x, &y)?;
            Vec::new()
        }
        p::Op::Cost => {
            if payload.is_empty() {
                anyhow::bail!("Cost request missing flag byte");
            }
            let has_tilde = payload[0] != 0;
            pos = 1;
            let c = if has_tilde {
                let tt = p::get_array(payload, &mut pos)?;
                dev.cost(Some(&tt))?
            } else {
                dev.cost(None)?
            };
            let mut out = Vec::with_capacity(4);
            p::put_f32(&mut out, c);
            out
        }
        p::Op::Evaluate => {
            let n = p::get_u32(payload, &mut pos)? as usize;
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            let (cost, correct) = dev.evaluate(&x, &y, n)?;
            let mut out = Vec::with_capacity(8);
            p::put_f32(&mut out, cost);
            p::put_f32(&mut out, correct);
            out
        }
        p::Op::CostMany => {
            let k = p::get_u32(payload, &mut pos)? as usize;
            let probes = p::get_array(payload, &mut pos)?;
            // The device validates probes.len() == k * P and holds θ and
            // the loaded batch fixed across the whole sub-batch.
            let costs = dev.cost_many(&probes, k)?;
            let mut out = Vec::with_capacity(4 + 4 * costs.len());
            p::put_array(&mut out, &costs);
            out
        }
        p::Op::Ping => {
            // Echo the payload verbatim (the client checks its nonce);
            // the device itself is not touched — Ping answers "is the
            // session alive", healthchecks answer "is the device sane".
            payload.to_vec()
        }
        p::Op::ModelSpec => {
            // Spec negotiation (see the protocol module docs): if the
            // client attached the spec it expects and this device exposes
            // one, a hash mismatch is a typed error — the client fails at
            // connect time instead of silently training the wrong
            // network.  The reply always carries the device's spec when
            // it has one.
            let client_spec = p::get_opt_spec(payload, &mut pos)?;
            let device_spec = dev.model_spec();
            if let (Some(want), Some(have)) = (&client_spec, &device_spec) {
                if want.spec_hash() != have.spec_hash() {
                    anyhow::bail!(
                        "model spec mismatch: client expects {want} (hash \
                         {:#018x}), device runs {have} (hash {:#018x})",
                        want.spec_hash(),
                        have.spec_hash()
                    );
                }
            }
            let mut out = Vec::new();
            p::put_opt_spec(&mut out, device_spec.as_ref());
            out
        }
        p::Op::Infer => {
            // Serving opcode on a training server: a HardwareDevice
            // exposes costs, not logits — answer with a typed error (the
            // session keeps serving) instead of pretending.
            anyhow::bail!(
                "Infer (0x0C) is an inference-serving opcode; this is a training \
                 device server — query an `mgd serve-infer` endpoint instead"
            );
        }
        p::Op::Stats => {
            // Live metrics snapshot; answered lease-free in
            // handle_session, but a leased session may poll it too.
            stats_reply()
        }
        p::Op::Bye => return Ok(None),
    };
    Ok(Some(reply))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NativeDevice;

    #[test]
    fn hello_reports_io_shape() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[49, 4, 4], 1));
        let reply = handle_request(&mut *dev, p::Op::Hello, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 220); // P
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 1); // B
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 49); // input_len
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 4); // n_outputs
    }

    #[test]
    fn dispatch_set_get_roundtrip() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let reply = handle_request(&mut *dev, p::Op::GetParams, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_array(&reply, &mut pos).unwrap(), vec![0.5; 9]);
    }

    #[test]
    fn dispatch_cost_flow() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        handle_request(&mut *dev, p::Op::SetParams, &{
            let mut b = Vec::new();
            p::put_array(&mut b, &[0.1; 9]);
            b
        })
        .unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        let reply = handle_request(&mut *dev, p::Op::Cost, &[0u8]).unwrap().unwrap();
        let mut pos = 0;
        let c = p::get_f32(&reply, &mut pos).unwrap();
        assert!(c.is_finite() && c >= 0.0);
    }

    #[test]
    fn dispatch_cost_many_matches_serial_costs() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.1; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        // Two probes through one CostMany frame…
        let probes: Vec<f32> = (0..18).map(|i| 0.01 * i as f32).collect();
        let mut req = Vec::new();
        p::put_u32(&mut req, 2);
        p::put_array(&mut req, &probes);
        let reply = handle_request(&mut *dev, p::Op::CostMany, &req).unwrap().unwrap();
        let mut pos = 0;
        let costs = p::get_array(&reply, &mut pos).unwrap();
        assert_eq!(costs.len(), 2);
        // …must equal two serial Cost dispatches, bit for bit.
        for (i, &c) in costs.iter().enumerate() {
            let mut req = vec![1u8];
            p::put_array(&mut req, &probes[i * 9..(i + 1) * 9]);
            let reply = handle_request(&mut *dev, p::Op::Cost, &req).unwrap().unwrap();
            let mut pos = 0;
            let serial = p::get_f32(&reply, &mut pos).unwrap();
            assert_eq!(c.to_bits(), serial.to_bits(), "probe {i}");
        }
    }

    #[test]
    fn dispatch_cost_many_rejects_mismatched_stack() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.1; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        // k = 3 but only 2 probes' worth of floats: device-side error,
        // not a panic, and the session would keep serving.
        let mut req = Vec::new();
        p::put_u32(&mut req, 3);
        p::put_array(&mut req, &[0.0; 18]);
        assert!(handle_request(&mut *dev, p::Op::CostMany, &req).is_err());
        // k = 0: legal, empty reply array.
        let mut req = Vec::new();
        p::put_u32(&mut req, 0);
        p::put_array(&mut req, &[]);
        let reply = handle_request(&mut *dev, p::Op::CostMany, &req).unwrap().unwrap();
        let mut pos = 0;
        assert!(p::get_array(&reply, &mut pos).unwrap().is_empty());
    }

    #[test]
    fn dispatch_ping_echoes_payload() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_u32(&mut payload, 1234);
        let reply = handle_request(&mut *dev, p::Op::Ping, &payload).unwrap().unwrap();
        assert_eq!(reply, payload);
        // Empty payload echoes empty.
        let reply = handle_request(&mut *dev, p::Op::Ping, &[]).unwrap().unwrap();
        assert!(reply.is_empty());
    }

    #[test]
    fn dispatch_model_spec_negotiates_and_rejects_mismatch() {
        use crate::model::ModelSpec;
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[49, 4, 4], 1));
        // Query (no client spec) returns the device's spec.
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, None);
        let reply = handle_request(&mut *dev, p::Op::ModelSpec, &req).unwrap().unwrap();
        let mut pos = 0;
        let got = p::get_opt_spec(&reply, &mut pos).unwrap().unwrap();
        assert_eq!(got.to_string(), "49x4x4:sigmoid,sigmoid");
        // Matching client spec is accepted.
        let spec: ModelSpec = "49x4x4".parse().unwrap();
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, Some(&spec));
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &req).is_ok());
        // Same P/B/in/out silhouette, different stack → typed error.  A
        // 49x4x4 relu net is indistinguishable from the sigmoid one
        // through Hello alone; the spec frame is what catches it.
        let wrong: ModelSpec = "49x4x4:relu,relu".parse().unwrap();
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, Some(&wrong));
        let err = handle_request(&mut *dev, p::Op::ModelSpec, &req).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("model spec mismatch"), "{msg}");
        assert!(msg.contains("49x4x4:relu,relu"), "{msg}");
        assert!(msg.contains("49x4x4:sigmoid,sigmoid"), "{msg}");
        // Malformed spec frame → error, not a panic (the session keeps
        // serving — errors are answered, see handle_session).
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &[9u8]).is_err());
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &[]).is_err());
    }

    #[test]
    fn dispatch_infer_is_a_typed_error_on_a_training_server() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_u32(&mut payload, 1);
        p::put_array(&mut payload, &[0.5, 0.5]);
        let err = handle_request(&mut *dev, p::Op::Infer, &payload).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("serve-infer"), "{msg}");
        // The session survives: a training request still works after.
        let reply = handle_request(&mut *dev, p::Op::Hello, &[]).unwrap().unwrap();
        assert!(!reply.is_empty());
    }

    #[test]
    fn dispatch_stats_returns_registry_snapshot() {
        crate::obs::counter("test_server_stats_total").inc();
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let reply = handle_request(&mut *dev, p::Op::Stats, &[]).unwrap().unwrap();
        let doc = crate::json::Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        let counters = doc.field("counters").unwrap();
        assert!(counters.field("test_server_stats_total").unwrap().as_u64().unwrap() >= 1);
        assert!(doc.get("gauges").is_some());
        assert!(doc.get("histograms").is_some());
        // The session survives a Stats poll.
        assert!(handle_request(&mut *dev, p::Op::Hello, &[]).is_ok());
    }

    #[test]
    fn stats_is_answered_lease_free_while_the_only_device_is_busy() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = DevicePool::new(vec![Box::new(NativeDevice::new(&[2, 2, 1], 1)) as _]);
        let server = {
            let pool = pool.clone();
            std::thread::spawn(move || {
                serve_pool(
                    pool,
                    listener,
                    ServeOptions {
                        max_sessions: Some(2),
                        // Short: if the Stats session wrongly tried to
                        // lease, it would fail here instead of hanging.
                        lease_timeout: Duration::from_millis(200),
                        telemetry: Telemetry::null(),
                    },
                )
                .unwrap();
            })
        };
        // Session 1 leases the pool's only device and stays open.
        let mut training = crate::device::RemoteDevice::connect(&addr).unwrap();
        assert_eq!(training.n_params(), 9);
        // Session 2 polls Stats — it must be answered even though every
        // device is out on a lease.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        p::write_request(&mut writer, p::Op::Stats, &[]).unwrap();
        let reply = p::read_response(&mut reader).unwrap();
        let doc = crate::json::Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        assert!(doc.get("counters").is_some());
        p::write_request(&mut writer, p::Op::Bye, &[]).unwrap();
        p::read_response(&mut reader).unwrap();
        training.close();
        server.join().unwrap();
    }

    #[test]
    fn unknown_opcode_over_tcp_is_an_error_response() {
        use std::io::{Read as _, Write as _};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(1)).unwrap();
        });
        let mut stream = TcpStream::connect(&addr).unwrap();
        // Opcode 0x0E is one past Stats: the server must answer a typed
        // error (same as the serve-infer endpoint) and close, not hang.
        stream.write_all(&[0x0Eu8, 0, 0, 0, 0]).unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let err = p::read_response(&mut reader).unwrap_err();
        assert!(format!("{err:#}").contains("unknown opcode"), "{err:#}");
        // The session closed after the error.
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty());
        server.join().unwrap();
    }

    #[test]
    fn dispatch_bye_ends_session() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        assert!(handle_request(&mut *dev, p::Op::Bye, &[]).unwrap().is_none());
    }

    #[test]
    fn dispatch_errors_do_not_panic() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        // Wrong param count → error, not panic.
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 3]);
        assert!(handle_request(&mut *dev, p::Op::SetParams, &payload).is_err());
        // Cost without a batch → error.
        assert!(handle_request(&mut *dev, p::Op::Cost, &[0u8]).is_err());
    }

    #[test]
    fn end_to_end_over_tcp() {
        use crate::device::RemoteDevice;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(1)).unwrap();
        });
        let mut remote = RemoteDevice::connect(&addr).unwrap();
        assert_eq!(remote.n_params(), 9);
        assert_eq!(remote.input_len(), 2);
        assert_eq!(
            remote.model_spec().expect("spec negotiated at connect").to_string(),
            "2x2x1:sigmoid,sigmoid"
        );
        remote.set_params(&[0.25; 9]).unwrap();
        remote.load_batch(&[1.0, 0.0], &[1.0]).unwrap();
        let c0 = remote.cost(None).unwrap();
        let c1 = remote.cost(Some(&[0.1; 9])).unwrap();
        assert!(c0.is_finite() && c1.is_finite());
        assert_ne!(c0, c1, "perturbation must change the cost");
        remote.apply_update(&[0.1; 9]).unwrap();
        let (cost, correct) = remote.evaluate(&[1.0, 0.0, 0.0, 0.0], &[1.0, 0.0], 2).unwrap();
        assert!(cost.is_finite() && correct <= 2.0);
        remote.close();
        server.join().unwrap();
    }

    #[test]
    fn demanded_spec_against_a_black_box_server_fails_as_unverifiable() {
        use crate::device::RemoteDevice;
        use crate::model::ModelSpec;
        /// A device that hides its model (the trait default): the
        /// paper's true black box.
        struct BlackBox(NativeDevice);
        impl HardwareDevice for BlackBox {
            fn n_params(&self) -> usize {
                self.0.n_params()
            }
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn input_len(&self) -> usize {
                self.0.input_len()
            }
            fn n_outputs(&self) -> usize {
                self.0.n_outputs()
            }
            fn set_params(&mut self, theta: &[f32]) -> Result<()> {
                self.0.set_params(theta)
            }
            fn get_params(&mut self) -> Result<Vec<f32>> {
                self.0.get_params()
            }
            fn apply_update(&mut self, delta: &[f32]) -> Result<()> {
                self.0.apply_update(delta)
            }
            fn load_batch(&mut self, x: &[f32], y: &[f32]) -> Result<()> {
                self.0.load_batch(x, y)
            }
            fn cost(&mut self, tt: Option<&[f32]>) -> Result<f32> {
                self.0.cost(tt)
            }
            fn evaluate(&mut self, x: &[f32], y: &[f32], n: usize) -> Result<(f32, f32)> {
                self.0.evaluate(x, y, n)
            }
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> =
                Box::new(BlackBox(NativeDevice::new(&[2, 2, 1], 1)));
            serve_on(dev, listener, Some(2)).unwrap();
        });
        // Demanding a spec the server cannot confirm must fail —
        // "unverifiable" is not "verified".
        let want: ModelSpec = "2x2x1".parse().unwrap();
        let err = RemoteDevice::connect_with_spec(&addr, Some(&want)).unwrap_err();
        assert!(format!("{err:#}").contains("unverifiable"), "{err:#}");
        // A spec-less connect accepts the black box on the Hello
        // handshake alone, exactly as before the negotiation existed.
        let remote = RemoteDevice::connect(&addr).unwrap();
        assert!(remote.model_spec().is_none());
        assert_eq!(remote.n_params(), 9);
        remote.close();
        server.join().unwrap();
    }

    #[test]
    fn spec_mismatch_over_tcp_fails_at_connect_not_mid_training() {
        use crate::device::RemoteDevice;
        use crate::model::ModelSpec;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(2)).unwrap();
        });
        // Wrong stack, same parameter count is irrelevant — the client
        // never even reaches SetParams: connect itself returns the typed
        // mismatch error (no hang, no silent corruption).
        let wrong: ModelSpec = "2x2x1:relu,relu".parse().unwrap();
        let err = RemoteDevice::connect_with_spec(&addr, Some(&wrong)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("model spec mismatch"), "{msg}");
        assert!(msg.contains("2x2x1:relu,relu"), "{msg}");
        // The server survives the rejection: a correct client connects
        // and trains on the next session.
        let right: ModelSpec = "2x2x1".parse().unwrap();
        let mut remote = RemoteDevice::connect_with_spec(&addr, Some(&right)).unwrap();
        remote.set_params(&[0.25; 9]).unwrap();
        remote.load_batch(&[1.0, 0.0], &[1.0]).unwrap();
        assert!(remote.cost(None).unwrap().is_finite());
        remote.close();
        server.join().unwrap();
    }
}
