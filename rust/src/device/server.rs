//! The lab-bench side of chip-in-the-loop training: serve hardware
//! devices over TCP.
//!
//! The seed implementation handled one session at a time — one chip, one
//! lab bench.  The fleet version serves a whole [`DevicePool`]: each
//! client session holds a pool lease for its lifetime (the protocol is
//! stateful — `LoadBatch` … `Cost` sequences must hit the same device).
//! A client that connects while every device is leased out waits for
//! one, bounded by [`ServeOptions::lease_timeout`]; on timeout its first
//! request is answered with a clean protocol error instead of a hang.
//!
//! Transport is the shared [`crate::net`] event loop: this module keeps
//! only protocol dispatch ([`handle_request`]) and session policy
//! ([`DeviceSession`]).  Slow device work runs on the loop's bounded
//! worker pool (one worker per pooled device by default), so thread
//! count is O(devices), not O(sessions), and idle keep-alive sessions
//! cost ~nothing.  Device leases are acquired *nonblockingly*
//! ([`DevicePool::lease_poll`]) with a short retry timer — a session
//! waiting for a device parks in the loop, never on a thread — and a
//! closing session retriggers its waiting siblings immediately, so the
//! condvar handoff of the blocking servers is preserved.
//!
//! `Stats = 0x0D` and `TraceDump = 0x0E` are the stateless exceptions:
//! they are answered from the process-global [`crate::obs`] registry /
//! span ring *before* (and without) taking a device lease, so a metrics
//! poller (`mgd top`) or a trace capture (`mgd trace`) neither consumes
//! hardware nor waits behind a training session.  Stats/TraceDump/Bye-
//! only sessions do not consume the `--max-sessions` budget either: the
//! budget counts device sessions, not pollers.
//!
//! Tracing: a frame that arrived with a trace-context rider (see the
//! protocol module) parents this server's spans under the *client's*
//! span — the lease wait is recorded via
//! [`crate::obs::trace::record_complete`] once granted, and the worker-
//! thread dispatch runs under a `dispatch` span whose children (e.g. the
//! exec sweep inside `cost_many`) nest via the worker's thread-local
//! context.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::protocol as p;
use super::HardwareDevice;
use crate::fleet::pool::{DeviceLease, DevicePool, LeasePoll};
use crate::fleet::telemetry::{Event, Telemetry};
use crate::net::{
    Action, EventLoop, Frame, Framing, NetOptions, Service, SessionBudget, SessionCx,
    SessionHandler, Timeouts,
};
use crate::obs::http::metrics_service;
use crate::obs::trace;

/// Pooled-server knobs.
pub struct ServeOptions {
    /// Stop accepting after this many sessions (in-flight sessions still
    /// complete before return).  `None` = serve forever.
    pub max_sessions: Option<usize>,
    /// How long a session waits for a free device before failing.
    pub lease_timeout: Duration,
    /// Event stream for session lifecycle.
    pub telemetry: Arc<Telemetry>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            max_sessions: None,
            lease_timeout: Duration::from_secs(30),
            telemetry: Telemetry::null(),
        }
    }
}

/// Serve a single `device` on `addr` (compatibility wrapper: a one-device
/// pool).
///
/// `max_sessions`: if `Some(n)`, return after `n` client sessions have
/// completed (used by tests and the chip-in-the-loop example).
pub fn serve(
    device: Box<dyn HardwareDevice>,
    addr: &str,
    max_sessions: Option<usize>,
) -> Result<()> {
    serve_with(device, addr, max_sessions, NetOptions::default())
}

/// [`serve`] with explicit transport knobs (worker count, idle/write
/// deadlines, a shared-loop metrics listener).
pub fn serve_with(
    device: Box<dyn HardwareDevice>,
    addr: &str,
    max_sessions: Option<usize>,
    net: NetOptions,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let pool = DevicePool::new(vec![device]);
    serve_pool_with(
        pool,
        listener,
        ServeOptions { max_sessions, lease_timeout: EFFECTIVELY_FOREVER, ..Default::default() },
        net,
    )
}

/// ~10 years; `Duration::MAX` risks platform-specific saturation quirks
/// in deadline arithmetic.
const EFFECTIVELY_FOREVER: Duration = Duration::from_secs(315_360_000);

/// Serve a single device on an already-bound listener (lets callers bind
/// port 0 and learn the real address before serving).
///
/// Matches the seed's serial-server semantics: a queued client waits for
/// the device as long as it takes (effectively no lease timeout), exactly
/// as it used to wait in the accept backlog.
pub fn serve_on(
    device: Box<dyn HardwareDevice>,
    listener: TcpListener,
    max_sessions: Option<usize>,
) -> Result<()> {
    let pool = DevicePool::new(vec![device]);
    serve_pool(
        pool,
        listener,
        ServeOptions { max_sessions, lease_timeout: EFFECTIVELY_FOREVER, ..Default::default() },
    )
}

/// Serve a whole device pool: concurrent sessions, each holding one
/// leased device for its lifetime.
pub fn serve_pool(
    pool: Arc<DevicePool>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    serve_pool_with(pool, listener, opts, NetOptions::default())
}

/// [`serve_pool`] with explicit transport knobs.  Sessions multiplex on
/// one event loop; device work runs on `net.workers` worker threads
/// (default: one per pooled device — more workers than devices cannot
/// help, every request needs a lease).
pub fn serve_pool_with(
    pool: Arc<DevicePool>,
    listener: TcpListener,
    opts: ServeOptions,
    net: NetOptions,
) -> Result<()> {
    eprintln!(
        "[device-server] pool of {} device(s) listening on {}",
        pool.size(),
        listener.local_addr()?
    );
    let workers = if net.workers > 0 { net.workers } else { pool.size().max(1) };
    let service = Arc::new(DeviceService {
        pool,
        budget: SessionBudget::new(opts.max_sessions),
        telemetry: opts.telemetry.clone(),
        lease_timeout: opts.lease_timeout,
        timeouts: Timeouts { idle: net.idle_timeout, write: net.write_timeout },
    });
    let mut el = EventLoop::new(workers)?;
    el.add_listener(listener, service, true)?;
    if let Some(metrics) = net.metrics {
        el.add_listener(metrics, metrics_service(), false)?;
    }
    el.run()
}

/// Poll cadence while a session waits for a device lease.  A closing
/// sibling retriggers waiters immediately, so this only bounds how fast
/// a session notices a device freed by *another pool user* (heartbeat
/// monitors, co-located trainers).
const LEASE_RETRY: Duration = Duration::from_millis(25);

/// The pool server as an event-loop [`Service`].
struct DeviceService {
    pool: Arc<DevicePool>,
    budget: Arc<SessionBudget>,
    telemetry: Arc<Telemetry>,
    lease_timeout: Duration,
    timeouts: Timeouts,
}

impl Service for DeviceService {
    fn framing(&self) -> Framing {
        Framing::Binary
    }

    fn open(&self, session: u64, peer: &str) -> Box<dyn SessionHandler> {
        eprintln!("[device-server] session {session} from {peer}");
        self.telemetry.emit(Event::SessionOpened { session, peer: peer.to_string() });
        Box::new(DeviceSession {
            pool: self.pool.clone(),
            budget: self.budget.clone(),
            telemetry: self.telemetry.clone(),
            session,
            requests: 0,
            counted: false,
            lease: None,
            pending: None,
            lease_started: None,
            lease_timeout: self.lease_timeout,
            closed_error: None,
        })
    }

    fn timeouts(&self) -> Timeouts {
        self.timeouts
    }

    fn is_done(&self) -> bool {
        self.budget.done()
    }
}

/// One client session over a pool lease.
///
/// Request counting matches the blocking server exactly: every
/// *processed* frame counts (lease-free Stats/Bye included), decode
/// errors and a lease-failed first request do not.
struct DeviceSession {
    pool: Arc<DevicePool>,
    budget: Arc<SessionBudget>,
    telemetry: Arc<Telemetry>,
    session: u64,
    requests: u64,
    /// Whether this session has consumed a `--max-sessions` slot.
    counted: bool,
    lease: Option<DeviceLease>,
    /// The frame awaiting device work (set before `Blocking`/`Wait`),
    /// with the trace context it rode in with (if any).
    pending: Option<(p::Op, Option<p::TraceCtx>, Vec<u8>)>,
    lease_started: Option<Instant>,
    lease_timeout: Duration,
    /// Set when the session ends in error (telemetry `ok:false`).
    closed_error: Option<String>,
}

impl DeviceSession {
    /// One nonblocking lease attempt; grants proceed to device work,
    /// contention arms the retry timer, terminal failures answer the
    /// pending request with the reason and close.
    fn lease_step(&mut self) -> Action {
        let started = *self.lease_started.get_or_insert_with(Instant::now);
        let waited = started.elapsed();
        let expired = waited >= self.lease_timeout;
        match self.pool.lease_poll(waited, self.lease_timeout, expired) {
            LeasePoll::Granted(lease) => {
                // Link the wait into the client's trace (explicit ctx
                // only: this runs on the loop thread, whose TLS context
                // belongs to the pump span, not this session).
                if let Some((_, Some(ctx), _)) = &self.pending {
                    let waited_ns = waited.as_nanos() as u64;
                    trace::record_complete(
                        trace::name::LEASE_WAIT,
                        Some(*ctx),
                        trace::now_ns().saturating_sub(waited_ns),
                        waited_ns,
                    );
                }
                self.lease = Some(lease);
                Action::Blocking
            }
            LeasePoll::Retry => {
                let remaining = self.lease_timeout.saturating_sub(waited);
                Action::Wait(LEASE_RETRY.min(remaining).max(Duration::from_millis(1)))
            }
            LeasePoll::Failed(e) => {
                // Answer the client's pending first request (Hello, for
                // RemoteDevice) with the reason before hanging up.
                let msg = format!("{e:#}");
                self.closed_error = Some(msg.clone());
                Action::ReplyClose(p::err_frame(&msg))
            }
        }
    }
}

impl SessionHandler for DeviceSession {
    fn on_frame(&mut self, frame: Frame, _cx: &SessionCx) -> Action {
        let Frame::Binary { op, ctx, payload } = frame else { return Action::Close };
        if self.lease.is_none() {
            // Stats, TraceDump (and a bare Bye) are answered before —
            // and without — a device lease: a metrics poller or trace
            // capture must never consume hardware, wait behind a
            // training session, or use up the session budget.  The
            // first stateful request below triggers the lease for the
            // rest of the session.
            match op {
                p::Op::Stats => {
                    self.requests += 1;
                    return Action::Reply(p::ok_frame(&stats_reply()));
                }
                p::Op::TraceDump => {
                    self.requests += 1;
                    return Action::Reply(p::ok_frame(&trace_reply()));
                }
                p::Op::Bye => {
                    self.requests += 1;
                    return Action::ReplyClose(p::ok_frame(&[]));
                }
                _ => {}
            }
            if !self.counted {
                self.counted = self.budget.try_start();
                if !self.counted {
                    return Action::ReplyClose(p::err_frame(
                        "server is draining: session budget (--max-sessions) exhausted",
                    ));
                }
            }
            self.pending = Some((op, ctx, payload));
            self.lease_started = Some(Instant::now());
            return self.lease_step();
        }
        self.pending = Some((op, ctx, payload));
        Action::Blocking
    }

    fn on_decode_error(&mut self, msg: &str) -> Action {
        // A malformed first frame is a (broken) device client, not a
        // metrics poller: it consumes budget so a bounded server still
        // drains.  The reply closes either way, so `try_start`'s verdict
        // does not gate the answer.
        if !self.counted {
            self.counted = self.budget.try_start();
        }
        Action::ReplyClose(p::err_frame(msg))
    }

    fn blocking(&mut self) -> Action {
        let Some((op, ctx, payload)) = self.pending.take() else { return Action::Close };
        self.requests += 1;
        let lease = self.lease.as_mut().expect("device work dispatched without a lease");
        // Worker-thread TLS is clean (no pump span), so this guard makes
        // every span the device opens (e.g. exec_sweep) a descendant of
        // the client's wire context.
        let _dispatch = trace::child_of(trace::name::DISPATCH, ctx);
        match handle_request(lease.device(), op, &payload) {
            Ok(Some(reply)) => Action::Reply(p::ok_frame(&reply)),
            Ok(None) => Action::ReplyClose(p::ok_frame(&[])), // Bye
            Err(e) => Action::Reply(p::err_frame(&format!("{e:#}"))),
        }
    }

    fn on_timer(&mut self) -> Action {
        self.lease_step()
    }

    fn on_close(&mut self) {
        if self.counted {
            self.budget.finish();
        }
        if let Some(err) = &self.closed_error {
            eprintln!("[device-server] session {} ended: {err}", self.session);
        }
        self.telemetry.emit(Event::SessionClosed {
            session: self.session,
            requests: self.requests,
            ok: self.closed_error.is_none(),
            error: self.closed_error.clone(),
        });
        // The lease itself (if any) releases when the handler drops,
        // right after this hook — on the loop thread, so a waiting
        // sibling's retry timer fires with the device already free.
    }
}

/// Render the `Stats` reply payload: the process-global [`crate::obs`]
/// registry as one JSON document.
fn stats_reply() -> Vec<u8> {
    crate::obs::snapshot().to_json().dump().into_bytes()
}

/// Render the `TraceDump` reply payload: the process-global span ring as
/// one Chrome trace-event JSON document.
fn trace_reply() -> Vec<u8> {
    trace::dump().into_bytes()
}

/// Dispatch one request. `Ok(None)` signals session end (Bye).
fn handle_request(
    dev: &mut dyn HardwareDevice,
    op: p::Op,
    payload: &[u8],
) -> Result<Option<Vec<u8>>> {
    let mut pos = 0usize;
    let reply = match op {
        p::Op::Hello => {
            let mut out = Vec::with_capacity(16);
            p::put_u32(&mut out, dev.n_params() as u32);
            p::put_u32(&mut out, dev.batch_size() as u32);
            p::put_u32(&mut out, dev.input_len() as u32);
            p::put_u32(&mut out, dev.n_outputs() as u32);
            out
        }
        p::Op::SetParams => {
            let theta = p::get_array(payload, &mut pos)?;
            dev.set_params(&theta)?;
            Vec::new()
        }
        p::Op::GetParams => {
            let theta = dev.get_params()?;
            let mut out = Vec::with_capacity(4 + 4 * theta.len());
            p::put_array(&mut out, &theta);
            out
        }
        p::Op::ApplyUpdate => {
            let delta = p::get_array(payload, &mut pos)?;
            dev.apply_update(&delta)?;
            Vec::new()
        }
        p::Op::LoadBatch => {
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            dev.load_batch(&x, &y)?;
            Vec::new()
        }
        p::Op::Cost => {
            if payload.is_empty() {
                anyhow::bail!("Cost request missing flag byte");
            }
            let has_tilde = payload[0] != 0;
            pos = 1;
            let c = if has_tilde {
                let tt = p::get_array(payload, &mut pos)?;
                dev.cost(Some(&tt))?
            } else {
                dev.cost(None)?
            };
            let mut out = Vec::with_capacity(4);
            p::put_f32(&mut out, c);
            out
        }
        p::Op::Evaluate => {
            let n = p::get_u32(payload, &mut pos)? as usize;
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            let (cost, correct) = dev.evaluate(&x, &y, n)?;
            let mut out = Vec::with_capacity(8);
            p::put_f32(&mut out, cost);
            p::put_f32(&mut out, correct);
            out
        }
        p::Op::CostMany => {
            let k = p::get_u32(payload, &mut pos)? as usize;
            let probes = p::get_array(payload, &mut pos)?;
            // The device validates probes.len() == k * P and holds θ and
            // the loaded batch fixed across the whole sub-batch.
            let costs = dev.cost_many(&probes, k)?;
            let mut out = Vec::with_capacity(4 + 4 * costs.len());
            p::put_array(&mut out, &costs);
            out
        }
        p::Op::Ping => {
            // Echo the payload verbatim (the client checks its nonce);
            // the device itself is not touched — Ping answers "is the
            // session alive", healthchecks answer "is the device sane".
            payload.to_vec()
        }
        p::Op::ModelSpec => {
            // Spec negotiation (see the protocol module docs): if the
            // client attached the spec it expects and this device exposes
            // one, a hash mismatch is a typed error — the client fails at
            // connect time instead of silently training the wrong
            // network.  The reply always carries the device's spec when
            // it has one.
            let client_spec = p::get_opt_spec(payload, &mut pos)?;
            let device_spec = dev.model_spec();
            if let (Some(want), Some(have)) = (&client_spec, &device_spec) {
                if want.spec_hash() != have.spec_hash() {
                    anyhow::bail!(
                        "model spec mismatch: client expects {want} (hash \
                         {:#018x}), device runs {have} (hash {:#018x})",
                        want.spec_hash(),
                        have.spec_hash()
                    );
                }
            }
            let mut out = Vec::new();
            p::put_opt_spec(&mut out, device_spec.as_ref());
            out
        }
        p::Op::Infer => {
            // Serving opcode on a training server: a HardwareDevice
            // exposes costs, not logits — answer with a typed error (the
            // session keeps serving) instead of pretending.
            anyhow::bail!(
                "Infer (0x0C) is an inference-serving opcode; this is a training \
                 device server — query an `mgd serve-infer` endpoint instead"
            );
        }
        p::Op::Stats => {
            // Live metrics snapshot; answered lease-free in
            // handle_session, but a leased session may poll it too.
            stats_reply()
        }
        p::Op::TraceDump => {
            // Span-ring export; answered lease-free like Stats, but a
            // leased session may capture it too.
            trace_reply()
        }
        p::Op::Bye => return Ok(None),
    };
    Ok(Some(reply))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NativeDevice;
    use std::io::{BufReader, BufWriter};
    use std::net::TcpStream;

    #[test]
    fn hello_reports_io_shape() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[49, 4, 4], 1));
        let reply = handle_request(&mut *dev, p::Op::Hello, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 220); // P
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 1); // B
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 49); // input_len
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 4); // n_outputs
    }

    #[test]
    fn dispatch_set_get_roundtrip() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let reply = handle_request(&mut *dev, p::Op::GetParams, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_array(&reply, &mut pos).unwrap(), vec![0.5; 9]);
    }

    #[test]
    fn dispatch_cost_flow() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        handle_request(&mut *dev, p::Op::SetParams, &{
            let mut b = Vec::new();
            p::put_array(&mut b, &[0.1; 9]);
            b
        })
        .unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        let reply = handle_request(&mut *dev, p::Op::Cost, &[0u8]).unwrap().unwrap();
        let mut pos = 0;
        let c = p::get_f32(&reply, &mut pos).unwrap();
        assert!(c.is_finite() && c >= 0.0);
    }

    #[test]
    fn dispatch_cost_many_matches_serial_costs() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.1; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        // Two probes through one CostMany frame…
        let probes: Vec<f32> = (0..18).map(|i| 0.01 * i as f32).collect();
        let mut req = Vec::new();
        p::put_u32(&mut req, 2);
        p::put_array(&mut req, &probes);
        let reply = handle_request(&mut *dev, p::Op::CostMany, &req).unwrap().unwrap();
        let mut pos = 0;
        let costs = p::get_array(&reply, &mut pos).unwrap();
        assert_eq!(costs.len(), 2);
        // …must equal two serial Cost dispatches, bit for bit.
        for (i, &c) in costs.iter().enumerate() {
            let mut req = vec![1u8];
            p::put_array(&mut req, &probes[i * 9..(i + 1) * 9]);
            let reply = handle_request(&mut *dev, p::Op::Cost, &req).unwrap().unwrap();
            let mut pos = 0;
            let serial = p::get_f32(&reply, &mut pos).unwrap();
            assert_eq!(c.to_bits(), serial.to_bits(), "probe {i}");
        }
    }

    #[test]
    fn dispatch_cost_many_rejects_mismatched_stack() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.1; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        // k = 3 but only 2 probes' worth of floats: device-side error,
        // not a panic, and the session would keep serving.
        let mut req = Vec::new();
        p::put_u32(&mut req, 3);
        p::put_array(&mut req, &[0.0; 18]);
        assert!(handle_request(&mut *dev, p::Op::CostMany, &req).is_err());
        // k = 0: legal, empty reply array.
        let mut req = Vec::new();
        p::put_u32(&mut req, 0);
        p::put_array(&mut req, &[]);
        let reply = handle_request(&mut *dev, p::Op::CostMany, &req).unwrap().unwrap();
        let mut pos = 0;
        assert!(p::get_array(&reply, &mut pos).unwrap().is_empty());
    }

    #[test]
    fn dispatch_ping_echoes_payload() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_u32(&mut payload, 1234);
        let reply = handle_request(&mut *dev, p::Op::Ping, &payload).unwrap().unwrap();
        assert_eq!(reply, payload);
        // Empty payload echoes empty.
        let reply = handle_request(&mut *dev, p::Op::Ping, &[]).unwrap().unwrap();
        assert!(reply.is_empty());
    }

    #[test]
    fn dispatch_model_spec_negotiates_and_rejects_mismatch() {
        use crate::model::ModelSpec;
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[49, 4, 4], 1));
        // Query (no client spec) returns the device's spec.
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, None);
        let reply = handle_request(&mut *dev, p::Op::ModelSpec, &req).unwrap().unwrap();
        let mut pos = 0;
        let got = p::get_opt_spec(&reply, &mut pos).unwrap().unwrap();
        assert_eq!(got.to_string(), "49x4x4:sigmoid,sigmoid");
        // Matching client spec is accepted.
        let spec: ModelSpec = "49x4x4".parse().unwrap();
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, Some(&spec));
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &req).is_ok());
        // Same P/B/in/out silhouette, different stack → typed error.  A
        // 49x4x4 relu net is indistinguishable from the sigmoid one
        // through Hello alone; the spec frame is what catches it.
        let wrong: ModelSpec = "49x4x4:relu,relu".parse().unwrap();
        let mut req = Vec::new();
        p::put_opt_spec(&mut req, Some(&wrong));
        let err = handle_request(&mut *dev, p::Op::ModelSpec, &req).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("model spec mismatch"), "{msg}");
        assert!(msg.contains("49x4x4:relu,relu"), "{msg}");
        assert!(msg.contains("49x4x4:sigmoid,sigmoid"), "{msg}");
        // Malformed spec frame → error, not a panic (the session keeps
        // serving — errors are answered, see handle_session).
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &[9u8]).is_err());
        assert!(handle_request(&mut *dev, p::Op::ModelSpec, &[]).is_err());
    }

    #[test]
    fn dispatch_infer_is_a_typed_error_on_a_training_server() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_u32(&mut payload, 1);
        p::put_array(&mut payload, &[0.5, 0.5]);
        let err = handle_request(&mut *dev, p::Op::Infer, &payload).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("serve-infer"), "{msg}");
        // The session survives: a training request still works after.
        let reply = handle_request(&mut *dev, p::Op::Hello, &[]).unwrap().unwrap();
        assert!(!reply.is_empty());
    }

    #[test]
    fn dispatch_stats_returns_registry_snapshot() {
        crate::obs::counter("test_server_stats_total").inc();
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let reply = handle_request(&mut *dev, p::Op::Stats, &[]).unwrap().unwrap();
        let doc = crate::json::Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        let counters = doc.field("counters").unwrap();
        assert!(counters.field("test_server_stats_total").unwrap().as_u64().unwrap() >= 1);
        assert!(doc.get("gauges").is_some());
        assert!(doc.get("histograms").is_some());
        // The session survives a Stats poll.
        assert!(handle_request(&mut *dev, p::Op::Hello, &[]).is_ok());
    }

    #[test]
    fn dispatch_trace_dump_returns_trace_event_json() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let reply = handle_request(&mut *dev, p::Op::TraceDump, &[]).unwrap().unwrap();
        let doc = crate::json::Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        assert!(doc.field("traceEvents").unwrap().as_arr().is_ok());
        // The session survives a trace capture.
        assert!(handle_request(&mut *dev, p::Op::Hello, &[]).is_ok());
    }

    #[test]
    fn stats_is_answered_lease_free_while_the_only_device_is_busy() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let pool = DevicePool::new(vec![Box::new(NativeDevice::new(&[2, 2, 1], 1)) as _]);
        let server = {
            let pool = pool.clone();
            std::thread::spawn(move || {
                serve_pool(
                    pool,
                    listener,
                    ServeOptions {
                        // One budgeted session: the training client.  The
                        // Stats poller must ride for free, or this server
                        // would never drain.
                        max_sessions: Some(1),
                        // Short: if the Stats session wrongly tried to
                        // lease, it would fail here instead of hanging.
                        lease_timeout: Duration::from_millis(200),
                        telemetry: Telemetry::null(),
                    },
                )
                .unwrap();
            })
        };
        // Session 1 leases the pool's only device and stays open.
        let mut training = crate::device::RemoteDevice::connect(&addr).unwrap();
        assert_eq!(training.n_params(), 9);
        // Session 2 polls Stats — it must be answered even though every
        // device is out on a lease.
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        p::write_request(&mut writer, p::Op::Stats, &[]).unwrap();
        let reply = p::read_response(&mut reader).unwrap();
        let doc = crate::json::Json::parse(std::str::from_utf8(&reply).unwrap()).unwrap();
        assert!(doc.get("counters").is_some());
        p::write_request(&mut writer, p::Op::Bye, &[]).unwrap();
        p::read_response(&mut reader).unwrap();
        training.close();
        server.join().unwrap();
    }

    #[test]
    fn unknown_opcode_over_tcp_is_an_error_response() {
        use std::io::{Read as _, Write as _};
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(1)).unwrap();
        });
        let mut stream = TcpStream::connect(&addr).unwrap();
        // Opcode 0x0F is one past TraceDump: the server must answer a
        // typed error (same as the serve-infer endpoint) and close, not
        // hang.
        stream.write_all(&[0x0Fu8, 0, 0, 0, 0]).unwrap();
        stream.flush().unwrap();
        let mut reader = BufReader::new(stream);
        let err = p::read_response(&mut reader).unwrap_err();
        assert!(format!("{err:#}").contains("unknown opcode"), "{err:#}");
        // The session closed after the error.
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty());
        server.join().unwrap();
    }

    #[test]
    fn dispatch_bye_ends_session() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        assert!(handle_request(&mut *dev, p::Op::Bye, &[]).unwrap().is_none());
    }

    #[test]
    fn dispatch_errors_do_not_panic() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        // Wrong param count → error, not panic.
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 3]);
        assert!(handle_request(&mut *dev, p::Op::SetParams, &payload).is_err());
        // Cost without a batch → error.
        assert!(handle_request(&mut *dev, p::Op::Cost, &[0u8]).is_err());
    }

    #[test]
    fn end_to_end_over_tcp() {
        use crate::device::RemoteDevice;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(1)).unwrap();
        });
        let mut remote = RemoteDevice::connect(&addr).unwrap();
        assert_eq!(remote.n_params(), 9);
        assert_eq!(remote.input_len(), 2);
        assert_eq!(
            remote.model_spec().expect("spec negotiated at connect").to_string(),
            "2x2x1:sigmoid,sigmoid"
        );
        remote.set_params(&[0.25; 9]).unwrap();
        remote.load_batch(&[1.0, 0.0], &[1.0]).unwrap();
        let c0 = remote.cost(None).unwrap();
        let c1 = remote.cost(Some(&[0.1; 9])).unwrap();
        assert!(c0.is_finite() && c1.is_finite());
        assert_ne!(c0, c1, "perturbation must change the cost");
        remote.apply_update(&[0.1; 9]).unwrap();
        let (cost, correct) = remote.evaluate(&[1.0, 0.0, 0.0, 0.0], &[1.0, 0.0], 2).unwrap();
        assert!(cost.is_finite() && correct <= 2.0);
        remote.close();
        server.join().unwrap();
    }

    #[test]
    fn demanded_spec_against_a_black_box_server_fails_as_unverifiable() {
        use crate::device::RemoteDevice;
        use crate::model::ModelSpec;
        /// A device that hides its model (the trait default): the
        /// paper's true black box.
        struct BlackBox(NativeDevice);
        impl HardwareDevice for BlackBox {
            fn n_params(&self) -> usize {
                self.0.n_params()
            }
            fn batch_size(&self) -> usize {
                self.0.batch_size()
            }
            fn input_len(&self) -> usize {
                self.0.input_len()
            }
            fn n_outputs(&self) -> usize {
                self.0.n_outputs()
            }
            fn set_params(&mut self, theta: &[f32]) -> Result<()> {
                self.0.set_params(theta)
            }
            fn get_params(&mut self) -> Result<Vec<f32>> {
                self.0.get_params()
            }
            fn apply_update(&mut self, delta: &[f32]) -> Result<()> {
                self.0.apply_update(delta)
            }
            fn load_batch(&mut self, x: &[f32], y: &[f32]) -> Result<()> {
                self.0.load_batch(x, y)
            }
            fn cost(&mut self, tt: Option<&[f32]>) -> Result<f32> {
                self.0.cost(tt)
            }
            fn evaluate(&mut self, x: &[f32], y: &[f32], n: usize) -> Result<(f32, f32)> {
                self.0.evaluate(x, y, n)
            }
        }
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> =
                Box::new(BlackBox(NativeDevice::new(&[2, 2, 1], 1)));
            serve_on(dev, listener, Some(2)).unwrap();
        });
        // Demanding a spec the server cannot confirm must fail —
        // "unverifiable" is not "verified".
        let want: ModelSpec = "2x2x1".parse().unwrap();
        let err = RemoteDevice::connect_with_spec(&addr, Some(&want)).unwrap_err();
        assert!(format!("{err:#}").contains("unverifiable"), "{err:#}");
        // A spec-less connect accepts the black box on the Hello
        // handshake alone, exactly as before the negotiation existed.
        let remote = RemoteDevice::connect(&addr).unwrap();
        assert!(remote.model_spec().is_none());
        assert_eq!(remote.n_params(), 9);
        remote.close();
        server.join().unwrap();
    }

    #[test]
    fn spec_mismatch_over_tcp_fails_at_connect_not_mid_training() {
        use crate::device::RemoteDevice;
        use crate::model::ModelSpec;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(2)).unwrap();
        });
        // Wrong stack, same parameter count is irrelevant — the client
        // never even reaches SetParams: connect itself returns the typed
        // mismatch error (no hang, no silent corruption).
        let wrong: ModelSpec = "2x2x1:relu,relu".parse().unwrap();
        let err = RemoteDevice::connect_with_spec(&addr, Some(&wrong)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("model spec mismatch"), "{msg}");
        assert!(msg.contains("2x2x1:relu,relu"), "{msg}");
        // The server survives the rejection: a correct client connects
        // and trains on the next session.
        let right: ModelSpec = "2x2x1".parse().unwrap();
        let mut remote = RemoteDevice::connect_with_spec(&addr, Some(&right)).unwrap();
        remote.set_params(&[0.25; 9]).unwrap();
        remote.load_batch(&[1.0, 0.0], &[1.0]).unwrap();
        assert!(remote.cost(None).unwrap().is_finite());
        remote.close();
        server.join().unwrap();
    }
}
