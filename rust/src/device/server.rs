//! The lab-bench side of chip-in-the-loop training: serve a local
//! [`HardwareDevice`] over TCP.
//!
//! Sessions are handled one at a time — hardware is a serially-shared
//! resource (the paper's chip sits on one lab bench); a queued client
//! blocks until the current session ends.  Plain `std::net` blocking I/O
//! on an accept thread (this offline build has no async runtime; the
//! protocol is strictly request/response so blocking I/O is exact).

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::protocol as p;
use super::HardwareDevice;

/// Serve `device` on `addr`.
///
/// `max_sessions`: if `Some(n)`, return after `n` client sessions have
/// completed (used by tests and the chip-in-the-loop example).
pub fn serve(
    device: Box<dyn HardwareDevice>,
    addr: &str,
    max_sessions: Option<usize>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_on(device, listener, max_sessions)
}

/// Serve on an already-bound listener (lets callers bind port 0 and learn
/// the real address before serving).
pub fn serve_on(
    device: Box<dyn HardwareDevice>,
    listener: TcpListener,
    max_sessions: Option<usize>,
) -> Result<()> {
    eprintln!(
        "[device-server] {} listening on {}",
        device.describe(),
        listener.local_addr()?
    );
    let device = Arc::new(Mutex::new(device));
    let mut sessions = 0usize;
    for stream in listener.incoming() {
        let stream = stream?;
        if let Ok(peer) = stream.peer_addr() {
            eprintln!("[device-server] session from {peer}");
        }
        if let Err(e) = handle_session(stream, device.clone()) {
            eprintln!("[device-server] session ended: {e:#}");
        }
        sessions += 1;
        if let Some(max) = max_sessions {
            if sessions >= max {
                return Ok(());
            }
        }
    }
    Ok(())
}

fn handle_session(
    stream: TcpStream,
    device: Arc<Mutex<Box<dyn HardwareDevice>>>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let (op, payload) = match p::read_request(&mut reader) {
            Ok(req) => req,
            // Client hung up without Bye — fine.
            Err(_) => return Ok(()),
        };
        let mut dev = device.lock().unwrap();
        match handle_request(&mut **dev, op, &payload) {
            Ok(Some(reply)) => p::write_ok(&mut writer, &reply)?,
            Ok(None) => {
                p::write_ok(&mut writer, &[])?;
                return Ok(()); // Bye
            }
            Err(e) => p::write_err(&mut writer, &format!("{e:#}"))?,
        }
    }
}

/// Dispatch one request. `Ok(None)` signals session end (Bye).
fn handle_request(
    dev: &mut dyn HardwareDevice,
    op: p::Op,
    payload: &[u8],
) -> Result<Option<Vec<u8>>> {
    let mut pos = 0usize;
    let reply = match op {
        p::Op::Hello => {
            let mut out = Vec::with_capacity(16);
            p::put_u32(&mut out, dev.n_params() as u32);
            p::put_u32(&mut out, dev.batch_size() as u32);
            p::put_u32(&mut out, dev.input_len() as u32);
            p::put_u32(&mut out, dev.n_outputs() as u32);
            out
        }
        p::Op::SetParams => {
            let theta = p::get_array(payload, &mut pos)?;
            dev.set_params(&theta)?;
            Vec::new()
        }
        p::Op::GetParams => {
            let theta = dev.get_params()?;
            let mut out = Vec::with_capacity(4 + 4 * theta.len());
            p::put_array(&mut out, &theta);
            out
        }
        p::Op::ApplyUpdate => {
            let delta = p::get_array(payload, &mut pos)?;
            dev.apply_update(&delta)?;
            Vec::new()
        }
        p::Op::LoadBatch => {
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            dev.load_batch(&x, &y)?;
            Vec::new()
        }
        p::Op::Cost => {
            if payload.is_empty() {
                anyhow::bail!("Cost request missing flag byte");
            }
            let has_tilde = payload[0] != 0;
            pos = 1;
            let c = if has_tilde {
                let tt = p::get_array(payload, &mut pos)?;
                dev.cost(Some(&tt))?
            } else {
                dev.cost(None)?
            };
            let mut out = Vec::with_capacity(4);
            p::put_f32(&mut out, c);
            out
        }
        p::Op::Evaluate => {
            let n = p::get_u32(payload, &mut pos)? as usize;
            let x = p::get_array(payload, &mut pos)?;
            let y = p::get_array(payload, &mut pos)?;
            let (cost, correct) = dev.evaluate(&x, &y, n)?;
            let mut out = Vec::with_capacity(8);
            p::put_f32(&mut out, cost);
            p::put_f32(&mut out, correct);
            out
        }
        p::Op::Bye => return Ok(None),
    };
    Ok(Some(reply))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::NativeDevice;

    #[test]
    fn hello_reports_io_shape() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[49, 4, 4], 1));
        let reply = handle_request(&mut *dev, p::Op::Hello, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 220); // P
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 1); // B
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 49); // input_len
        assert_eq!(p::get_u32(&reply, &mut pos).unwrap(), 4); // n_outputs
    }

    #[test]
    fn dispatch_set_get_roundtrip() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 9]);
        handle_request(&mut *dev, p::Op::SetParams, &payload).unwrap();
        let reply = handle_request(&mut *dev, p::Op::GetParams, &[]).unwrap().unwrap();
        let mut pos = 0;
        assert_eq!(p::get_array(&reply, &mut pos).unwrap(), vec![0.5; 9]);
    }

    #[test]
    fn dispatch_cost_flow() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        handle_request(&mut *dev, p::Op::SetParams, &{
            let mut b = Vec::new();
            p::put_array(&mut b, &[0.1; 9]);
            b
        })
        .unwrap();
        let mut batch = Vec::new();
        p::put_array(&mut batch, &[1.0, 0.0]);
        p::put_array(&mut batch, &[1.0]);
        handle_request(&mut *dev, p::Op::LoadBatch, &batch).unwrap();
        let reply = handle_request(&mut *dev, p::Op::Cost, &[0u8]).unwrap().unwrap();
        let mut pos = 0;
        let c = p::get_f32(&reply, &mut pos).unwrap();
        assert!(c.is_finite() && c >= 0.0);
    }

    #[test]
    fn dispatch_bye_ends_session() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        assert!(handle_request(&mut *dev, p::Op::Bye, &[]).unwrap().is_none());
    }

    #[test]
    fn dispatch_errors_do_not_panic() {
        let mut dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
        // Wrong param count → error, not panic.
        let mut payload = Vec::new();
        p::put_array(&mut payload, &[0.5; 3]);
        assert!(handle_request(&mut *dev, p::Op::SetParams, &payload).is_err());
        // Cost without a batch → error.
        assert!(handle_request(&mut *dev, p::Op::Cost, &[0u8]).is_err());
    }

    #[test]
    fn end_to_end_over_tcp() {
        use crate::device::RemoteDevice;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let dev: Box<dyn HardwareDevice> = Box::new(NativeDevice::new(&[2, 2, 1], 1));
            serve_on(dev, listener, Some(1)).unwrap();
        });
        let mut remote = RemoteDevice::connect(&addr).unwrap();
        assert_eq!(remote.n_params(), 9);
        assert_eq!(remote.input_len(), 2);
        remote.set_params(&[0.25; 9]).unwrap();
        remote.load_batch(&[1.0, 0.0], &[1.0]).unwrap();
        let c0 = remote.cost(None).unwrap();
        let c1 = remote.cost(Some(&[0.1; 9])).unwrap();
        assert!(c0.is_finite() && c1.is_finite());
        assert_ne!(c0, c1, "perturbation must change the cost");
        remote.apply_update(&[0.1; 9]).unwrap();
        let (cost, correct) = remote.evaluate(&[1.0, 0.0, 0.0, 0.0], &[1.0, 0.0], 2).unwrap();
        assert!(cost.is_finite() && correct <= 2.0);
        remote.close();
        server.join().unwrap();
    }
}
