//! PJRT-backed hardware device: the AOT-compiled JAX/Pallas model.
//!
//! This is the "emerging hardware platform" of the reproduction: inference
//! is an opaque compiled executable (HLO produced once at build time by
//! `python/compile/aot.py`); the MGD coordinator interacts with it only
//! through the [`HardwareDevice`] cost interface.  Python never runs here.

use anyhow::{bail, Context, Result};

use super::HardwareDevice;
use crate::model::{Activation, ModelSpec};
use crate::runtime::{Executable, Runtime, Value};
use std::sync::Arc;

/// A model instance on the PJRT CPU client.
pub struct PjrtDevice {
    model: String,
    /// Typed spec reconstructed from the manifest (`None` for models the
    /// manifest cannot describe as a dense stack, e.g. CNNs).
    spec: Option<ModelSpec>,
    cost_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    theta: Vec<f32>,
    zeros: Vec<f32>,
    batch: usize,
    input_len: usize,
    n_outputs: usize,
    eval_batch: usize,
    x: Vec<f32>,
    y: Vec<f32>,
    x_shape: Vec<usize>,
    eval_x_shape: Vec<usize>,
}

impl PjrtDevice {
    /// Instantiate the named model (`xor221`, `nist744`, ...) from the
    /// runtime's manifest.  Parameters start at zero; call
    /// [`HardwareDevice::set_params`] before training.
    pub fn new(rt: &Runtime, model: &str) -> Result<Self> {
        let meta = rt.manifest.model(model)?.clone();
        let cost_exe = rt
            .executable(&format!("{model}_cost"))
            .with_context(|| format!("loading cost artifact for {model}"))?;
        let eval_exe = rt
            .executable(&format!("{model}_eval"))
            .with_context(|| format!("loading eval artifact for {model}"))?;
        let p = meta.param_count;
        let mut x_shape = vec![meta.batch_cost];
        x_shape.extend_from_slice(&meta.input_shape);
        let mut eval_x_shape = vec![meta.batch_eval];
        eval_x_shape.extend_from_slice(&meta.input_shape);
        Ok(PjrtDevice {
            model: model.to_string(),
            spec: spec_from_meta(&meta),
            cost_exe,
            eval_exe,
            theta: vec![0.0; p],
            zeros: vec![0.0; p],
            batch: meta.batch_cost,
            input_len: meta.input_len(),
            n_outputs: meta.n_outputs,
            eval_batch: meta.batch_eval,
            x: Vec::new(),
            y: Vec::new(),
            x_shape,
            eval_x_shape,
        })
    }

    /// The model id this device runs.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Instantiate a device for a typed [`ModelSpec`]: the manifest is
    /// searched for a model whose dense stack matches the spec (by
    /// [`ModelSpec::spec_hash`]), falling back to a model registered
    /// under the spec's canonical [`ModelSpec::artifact_stem`] name.
    /// Either way the `{name}_cost` / `{name}_eval` artifact pair is
    /// what loads — the spec, not a stringly-typed model id, decides the
    /// artifacts.
    pub fn for_spec(rt: &Runtime, spec: &ModelSpec) -> Result<Self> {
        let want = spec.spec_hash();
        let mut names: Vec<&String> = rt.manifest.models.keys().collect();
        names.sort(); // deterministic pick if several models share a stack
        for name in names {
            let meta = rt.manifest.model(name)?;
            if spec_from_meta(meta).is_some_and(|s| s.spec_hash() == want) {
                return Self::new(rt, name);
            }
        }
        let stem = spec.artifact_stem();
        if rt.manifest.models.contains_key(&stem) {
            return Self::new(rt, &stem);
        }
        bail!(
            "no AOT artifacts for model spec {spec}: the manifest has no model with \
             that dense stack; compile one (python/compile/aot.py) under the canonical \
             name {stem:?} ({stem}_cost / {stem}_eval)"
        )
    }
}

/// Reconstruct the typed spec a manifest MLP entry describes: `layers`
/// widths plus `activation` — either a single broadcast token (the
/// legacy form, defaulting to the paper's sigmoid) or a comma-separated
/// per-layer list, which is how `python/compile/aot.py` records
/// mixed-activation grammar specs.
fn spec_from_meta(meta: &crate::runtime::ModelMeta) -> Option<ModelSpec> {
    let widths = meta.layers.as_deref()?;
    let acts: Vec<Activation> = match &meta.activation {
        Some(names) => names
            .split(',')
            .map(|t| t.trim().parse::<Activation>())
            .collect::<anyhow::Result<_>>()
            .ok()?,
        None => vec![Activation::Sigmoid],
    };
    ModelSpec::mlp(widths, &acts).ok()
}

impl HardwareDevice for PjrtDevice {
    fn n_params(&self) -> usize {
        self.theta.len()
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn input_len(&self) -> usize {
        self.input_len
    }

    fn n_outputs(&self) -> usize {
        self.n_outputs
    }

    fn model_spec(&self) -> Option<ModelSpec> {
        self.spec.clone()
    }

    fn set_params(&mut self, theta: &[f32]) -> Result<()> {
        if theta.len() != self.theta.len() {
            bail!("set_params: expected {} params, got {}", self.theta.len(), theta.len());
        }
        self.theta.copy_from_slice(theta);
        Ok(())
    }

    fn get_params(&mut self) -> Result<Vec<f32>> {
        Ok(self.theta.clone())
    }

    fn apply_update(&mut self, delta: &[f32]) -> Result<()> {
        if delta.len() != self.theta.len() {
            bail!("apply_update: expected {} params, got {}", self.theta.len(), delta.len());
        }
        for (t, d) in self.theta.iter_mut().zip(delta) {
            *t += d;
        }
        Ok(())
    }

    fn load_batch(&mut self, x: &[f32], y: &[f32]) -> Result<()> {
        if x.len() != self.batch * self.input_len || y.len() != self.batch * self.n_outputs {
            bail!(
                "load_batch: expected x[{}] y[{}], got x[{}] y[{}]",
                self.batch * self.input_len,
                self.batch * self.n_outputs,
                x.len(),
                y.len()
            );
        }
        self.x = x.to_vec();
        self.y = y.to_vec();
        Ok(())
    }

    fn cost(&mut self, theta_tilde: Option<&[f32]>) -> Result<f32> {
        if self.x.is_empty() {
            bail!("cost: no batch loaded");
        }
        let tt = match theta_tilde {
            Some(tt) if tt.len() != self.theta.len() => {
                bail!("cost: perturbation length {} != {}", tt.len(), self.theta.len())
            }
            Some(tt) => tt,
            None => &self.zeros,
        };
        let p = self.theta.len();
        let out = self.cost_exe.run(&[
            Value::f32(self.theta.clone(), &[p]),
            Value::f32(tt.to_vec(), &[p]),
            Value::f32(self.x.clone(), &self.x_shape),
            Value::f32(self.y.clone(), &[self.batch, self.n_outputs]),
        ])?;
        out[0].to_scalar_f32()
    }

    // `cost_many` deliberately stays on the trait default (K serial
    // dispatches through `cost`): the `cost` artifact is compiled for a
    // single θ̃ input, so there is nothing to batch yet.  A vmapped
    // `{model}_cost_many` artifact (one PJRT call for all K probes) is
    // the ROADMAP follow-on once real xla bindings land.

    fn evaluate(&mut self, x: &[f32], y: &[f32], n: usize) -> Result<(f32, f32)> {
        if x.len() != n * self.input_len || y.len() != n * self.n_outputs {
            bail!("evaluate: shape mismatch");
        }
        // The eval artifact has a fixed batch; run in chunks, padding the
        // tail by wrapping (padded duplicates are excluded from counts).
        let b = self.eval_batch;
        let p = self.theta.len();
        let mut total_cost = 0f64;
        let mut total_correct = 0f64;
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(b);
            let mut xb = Vec::with_capacity(b * self.input_len);
            let mut yb = Vec::with_capacity(b * self.n_outputs);
            for j in 0..b {
                let src = done + (j % take);
                xb.extend_from_slice(&x[src * self.input_len..(src + 1) * self.input_len]);
                yb.extend_from_slice(&y[src * self.n_outputs..(src + 1) * self.n_outputs]);
            }
            let out = self.eval_exe.run(&[
                Value::f32(self.theta.clone(), &[p]),
                Value::f32(xb, &self.eval_x_shape),
                Value::f32(yb, &[b, self.n_outputs]),
            ])?;
            let cost = out[0].to_scalar_f32()? as f64;
            let correct = out[1].to_scalar_f32()? as f64;
            // Padded chunk: correct-count includes duplicates; rescale.
            let scale = take as f64 / b as f64;
            total_cost += cost * take as f64;
            total_correct += correct * scale;
            done += take;
        }
        Ok(((total_cost / n as f64) as f32, total_correct as f32))
    }

    fn describe(&self) -> String {
        format!("pjrt:{}(P={}, B={})", self.model, self.theta.len(), self.batch)
    }
}
