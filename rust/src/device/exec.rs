//! The shared layer-sweep executor: one set of forward kernels for the
//! training and inference paths.
//!
//! Before the serving subsystem existed these functions lived inside
//! [`super::NativeDevice`].  Serving needs the *same arithmetic* — a
//! checkpoint trained on the device must answer queries with exactly the
//! activations the trainer measured, or an accuracy number printed at
//! train time silently disagrees with the accuracy the served model
//! delivers.  Factoring the kernels here makes that a property of the
//! code shape instead of a test assertion: [`super::NativeDevice`] (the
//! training path) and [`crate::serve::InferenceEngine`] (the forward-only
//! serving path) call the **identical functions**, so their outputs are
//! bit-identical for the same θ by construction.  The regression pin
//! lives in `rust/tests/integration_serve.rs`.
//!
//! The split mirrors the multi-probe cost engine's two phases:
//!
//! - [`compute_layer0_base`] — the unperturbed first-layer
//!   pre-activations, probe-independent, computed once per device call;
//! - [`forward_one`] — the remaining walk for one probe (or the
//!   baseline / an inference pass when `tilde` is `None`).
//!
//! [`score_batch`] is the shared cost/accuracy head: the MSE cost plus
//! the prediction rule (`>0.5` for single-output networks, row argmax
//! otherwise) that [`super::HardwareDevice::evaluate`], the trainer's
//! accuracy probe and the serving path must all agree on — including the
//! tie-breaking of [`argmax_row`], which follows `Iterator::max_by`
//! (last maximum wins on exact ties).
//!
//! # Kernel modes
//!
//! The module is a small GEMM-like kernel library with three
//! interchangeable implementations, selected by [`KernelMode`]
//! (`MGD_EXEC_KERNEL=scalar|blocked|simd`, or [`set_kernel_mode`]):
//!
//! - **Scalar** (default) — the loops above, byte-for-byte the
//!   pre-library executor.  This is the bitwise-pinned reference every
//!   determinism test is built on.
//! - **Blocked** — cache-blocked/tiled sweeps ([`SAMPLE_BLOCK`] ×
//!   [`COL_BLOCK`] accumulator panels, θ panels walked once per block)
//!   over portable 8-lane f32 arrays, plus the batch-major probe layout
//!   of [`sweep_probe_block`] (θ panels shared across [`PROBE_BLOCK`]
//!   probes of a `CostMany` frame).
//! - **Simd** — the Blocked loop structure with explicit x86-64
//!   intrinsics (8-wide AVX when the CPU has it, 4-wide SSE2 otherwise;
//!   the portable lanes off x86-64).
//!
//! All three are **bit-identical** by construction: the one inner
//! operation is an axpy over the output-neuron axis (`z[j] += h·w[j]`)
//! whose lanes are independent `mul`-then-`add` pairs (never an FMA,
//! which rounds once instead of twice), the accumulation order over the
//! input axis stays `i = 0..width` for every `(sample, j)` element in
//! every mode, and activations (the only cross-lane arithmetic) run the
//! identical scalar code everywhere.  The vectorized modes are pinned
//! against the scalar reference in `rust/tests/integration_model.rs`.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::model::{Activation, Dense};
use crate::noise::NeuronDefects;
use crate::obs;

/// Rows pushed through [`ForwardScratch::forward`], counted once per
/// batched call (never inside the layer kernels themselves).
fn rows_total() -> &'static obs::Counter {
    static M: std::sync::OnceLock<obs::Counter> = std::sync::OnceLock::new();
    M.get_or_init(|| obs::counter("mgd_exec_rows_total"))
}

/// Mean-squared error between a prediction block and its targets.
pub fn mse(y_pred: &[f32], y_true: &[f32]) -> f32 {
    debug_assert_eq!(y_pred.len(), y_true.len());
    let sum: f32 = y_pred
        .iter()
        .zip(y_true)
        .map(|(p, t)| {
            let d = p - t;
            d * d
        })
        .sum();
    sum / y_pred.len() as f32
}

/// Which kernel implementation the executor's inner loops run.
///
/// Every mode computes bit-identical results (see the module docs for
/// why); `Scalar` stays the pinned reference, the vectorized modes are
/// opt-in so trainer determinism baselines never move by default.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelMode {
    /// The pre-kernel-library scalar loops (the default).
    Scalar = 1,
    /// Cache-blocked/tiled sweeps over portable 8-lane f32 arrays.
    Blocked = 2,
    /// [`KernelMode::Blocked`]'s loop structure with explicit x86-64
    /// intrinsics (AVX when available, SSE2 otherwise).
    Simd = 3,
}

impl KernelMode {
    /// Parse an `MGD_EXEC_KERNEL` value.
    pub fn parse(s: &str) -> Option<KernelMode> {
        match s {
            "scalar" => Some(KernelMode::Scalar),
            "blocked" => Some(KernelMode::Blocked),
            "simd" => Some(KernelMode::Simd),
            _ => None,
        }
    }

    /// The canonical spelling (`MGD_EXEC_KERNEL` values).
    pub fn as_str(self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Blocked => "blocked",
            KernelMode::Simd => "simd",
        }
    }
}

/// Process-wide kernel mode; 0 means "read `MGD_EXEC_KERNEL` on first
/// use".  An atomic rather than a `OnceLock` so benches and tests can
/// flip modes at runtime ([`set_kernel_mode`]).
static KERNEL_MODE: AtomicU8 = AtomicU8::new(0);

/// The kernel mode the executor currently runs (env-initialized,
/// runtime-switchable).  Unknown `MGD_EXEC_KERNEL` values fall back to
/// the scalar reference.
pub fn kernel_mode() -> KernelMode {
    match KERNEL_MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Scalar,
        2 => KernelMode::Blocked,
        3 => KernelMode::Simd,
        _ => {
            let mode = std::env::var("MGD_EXEC_KERNEL")
                .ok()
                .and_then(|v| KernelMode::parse(&v))
                .unwrap_or(KernelMode::Scalar);
            KERNEL_MODE.store(mode as u8, Ordering::Relaxed);
            mode
        }
    }
}

/// Override the kernel mode for this process (benches, tests, CLI).
pub fn set_kernel_mode(mode: KernelMode) {
    KERNEL_MODE.store(mode as u8, Ordering::Relaxed);
}

/// Samples per block of the tiled layer sweep: weight panels are walked
/// once per sample block instead of once per sample.
pub const SAMPLE_BLOCK: usize = 8;

/// Output-neuron columns per accumulator tile.  A `SAMPLE_BLOCK ×
/// COL_BLOCK` f32 panel is 8 KiB — L1-resident while the input axis
/// streams the weight panel through it.
pub const COL_BLOCK: usize = 256;

/// Probes of a `CostMany` sweep forwarded per θ-panel walk by
/// [`sweep_probe_block`]: the batch-major layout treats the block's
/// `PROBE_BLOCK · n` activation rows as one extended sample batch, so a
/// θ panel is loaded once per block instead of once per probe.  Scratch
/// scales with this constant, not with K.
pub const PROBE_BLOCK: usize = 8;

/// Lane width of the portable microkernel (mirrors one AVX register).
const LANES: usize = 8;

/// Portable 8-lane axpy: `acc[j] += h · row[j]`.  Fixed-size lane
/// arrays give the compiler exact-width vectors; each lane is an
/// independent `mul` + `add`, exactly the scalar loop's arithmetic.
#[inline]
fn axpy_lanes(acc: &mut [f32], row: &[f32], h: f32) {
    let n = acc.len();
    let mut j = 0usize;
    while j + LANES <= n {
        let a: &mut [f32; LANES] = (&mut acc[j..j + LANES]).try_into().unwrap();
        let r: &[f32; LANES] = (&row[j..j + LANES]).try_into().unwrap();
        for l in 0..LANES {
            a[l] += h * r[l];
        }
        j += LANES;
    }
    while j < n {
        acc[j] += h * row[j];
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! x86-64 intrinsic axpy paths.  Strictly `mul` then `add` — never a
    //! fused multiply-add, which would round once where the scalar
    //! reference rounds twice — so every lane retires the scalar
    //! arithmetic bit-for-bit.
    use std::arch::x86_64::*;

    /// Whether this CPU offers 8-wide AVX (detected once).
    pub fn have_avx() -> bool {
        static AVX: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
        *AVX.get_or_init(|| is_x86_feature_detected!("avx"))
    }

    /// 8-wide AVX axpy.
    ///
    /// # Safety
    /// Requires AVX (callers gate on [`have_avx`]) and
    /// `row.len() >= acc.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy_avx(acc: &mut [f32], row: &[f32], h: f32) {
        debug_assert!(row.len() >= acc.len());
        let n = acc.len();
        let hv = _mm256_set1_ps(h);
        let mut j = 0usize;
        while j + 8 <= n {
            let a = _mm256_loadu_ps(acc.as_ptr().add(j));
            let r = _mm256_loadu_ps(row.as_ptr().add(j));
            _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_add_ps(a, _mm256_mul_ps(r, hv)));
            j += 8;
        }
        while j < n {
            *acc.get_unchecked_mut(j) += h * *row.get_unchecked(j);
            j += 1;
        }
    }

    /// 4-wide SSE2 axpy (baseline x86-64 — always present).
    ///
    /// # Safety
    /// Raw-pointer loads: requires `row.len() >= acc.len()`.
    pub unsafe fn axpy_sse2(acc: &mut [f32], row: &[f32], h: f32) {
        debug_assert!(row.len() >= acc.len());
        let n = acc.len();
        let hv = _mm_set1_ps(h);
        let mut j = 0usize;
        while j + 4 <= n {
            let a = _mm_loadu_ps(acc.as_ptr().add(j));
            let r = _mm_loadu_ps(row.as_ptr().add(j));
            _mm_storeu_ps(acc.as_mut_ptr().add(j), _mm_add_ps(a, _mm_mul_ps(r, hv)));
            j += 4;
        }
        while j < n {
            *acc.get_unchecked_mut(j) += h * *row.get_unchecked(j);
            j += 1;
        }
    }
}

/// `acc[j] += h · row[j]` over the output-neuron axis — the executor's
/// one inner operation.  The mode picks how many lanes retire per
/// instruction; it never changes a result bit (each element is the
/// scalar `mul` then `add`, in the same order).
#[inline]
fn axpy(acc: &mut [f32], row: &[f32], h: f32, mode: KernelMode) {
    #[cfg(target_arch = "x86_64")]
    if mode == KernelMode::Simd {
        // SAFETY: AVX is runtime-verified; SSE2 is baseline x86-64.
        // Both slices come from the same layer, so row covers acc.
        unsafe {
            if x86::have_avx() {
                x86::axpy_avx(acc, row, h);
            } else {
                x86::axpy_sse2(acc, row, h);
            }
        }
        return;
    }
    let _ = mode;
    axpy_lanes(acc, row, h);
}

/// One cache-blocked dense layer over `n` contiguous rows:
/// `z[s][j] = bias[j] + Σᵢ h[s][i] · w[i][j]`.
///
/// The loop nest is tiled over samples × output columns with the input
/// axis innermost-but-shared: each weight row slice is loaded once per
/// tile and applied to the whole sample block, so weight panels stay
/// cache-resident instead of being re-streamed per sample.  Per
/// `(s, j)` element the accumulation order over `i` is `0..width`,
/// identical to the scalar walk — the tiling moves loads, not rounding.
fn dense_layer_blocked(
    w: &[f32],
    bias: &[f32],
    h: &[f32],
    width: usize,
    n_out: usize,
    n: usize,
    z: &mut [f32],
    mode: KernelMode,
) {
    for s0 in (0..n).step_by(SAMPLE_BLOCK) {
        let sb = SAMPLE_BLOCK.min(n - s0);
        for s in s0..s0 + sb {
            z[s * n_out..(s + 1) * n_out].copy_from_slice(bias);
        }
        for j0 in (0..n_out).step_by(COL_BLOCK) {
            let jb = COL_BLOCK.min(n_out - j0);
            for i in 0..width {
                let wrow = &w[i * n_out + j0..i * n_out + j0 + jb];
                for s in s0..s0 + sb {
                    let hv = h[s * width + i];
                    let zrow = &mut z[s * n_out + j0..s * n_out + j0 + jb];
                    axpy(zrow, wrow, hv, mode);
                }
            }
        }
    }
}

/// Batched unperturbed forward pass on the blocked/SIMD kernels — the
/// fast-mode twin of [`compute_layer0_base`] + [`forward_one`] with
/// `tilde = None`, bit-identical to that pair for any input (pinned in
/// `rust/tests/integration_model.rs`).  `acts_a`/`acts_b` are ping-pong
/// blocks of at least `widest · n` floats; `out` receives
/// `n · layers.last().outputs` floats.
#[allow(clippy::too_many_arguments)]
pub fn forward_blocked(
    layers: &[Dense],
    theta: &[f32],
    defects: &NeuronDefects,
    x: &[f32],
    n: usize,
    acts_a: &mut [f32],
    acts_b: &mut [f32],
    out: &mut [f32],
    mode: KernelMode,
) {
    let mut cur: &mut [f32] = acts_a;
    let mut nxt: &mut [f32] = acts_b;
    let mut offset = 0usize;
    let mut neuron_base = 0usize;
    for (li, layer) in layers.iter().enumerate() {
        let width = layer.inputs;
        let n_out = layer.outputs;
        let wlen = width * n_out;
        let h: &[f32] = if li == 0 { x } else { cur };
        dense_layer_blocked(
            &theta[offset..offset + wlen],
            &theta[offset + wlen..offset + wlen + n_out],
            h,
            width,
            n_out,
            n,
            &mut nxt[..n * n_out],
            mode,
        );
        for s in 0..n {
            activate_row(
                layer.activation,
                defects,
                neuron_base,
                &mut nxt[s * n_out..(s + 1) * n_out],
            );
        }
        std::mem::swap(&mut cur, &mut nxt);
        offset += wlen + n_out;
        neuron_base += n_out;
    }
    let n_out = layers.last().unwrap().outputs;
    out.copy_from_slice(&cur[..n * n_out]);
}

/// Batch-major multi-probe sweep: evaluate `costs.len()` probes (each
/// `p` floats, stacked in `probes`) against the shared layer-0 `base`,
/// streaming them through θ in blocks of [`PROBE_BLOCK`].
///
/// Within a block the θ panels of every deeper layer are walked **once**
/// — each weight row is applied to all `PROBE_BLOCK · n` activation rows
/// before the next is loaded — while each probe's θ̃ panel streams
/// individually (probes share θ, never θ̃).  The perturbation term
/// accumulates into its own row and is added afterwards, exactly as the
/// scalar [`forward_one`] does, so per `(probe, sample, j)` element the
/// arithmetic and its order are unchanged: the sweep is bit-identical to
/// looping [`forward_one`] + [`mse`] probe by probe.
///
/// `acts_a`/`acts_b` are ping-pong blocks of `PROBE_BLOCK · widest · n`
/// floats; `pert_row` holds `widest`.  Memory therefore scales with
/// [`PROBE_BLOCK`], never with the probe count.
#[allow(clippy::too_many_arguments)]
pub fn sweep_probe_block(
    layers: &[Dense],
    theta: &[f32],
    defects: &NeuronDefects,
    x: &[f32],
    n: usize,
    base: &[f32],
    probes: &[f32],
    p: usize,
    y: &[f32],
    widest: usize,
    acts_a: &mut [f32],
    acts_b: &mut [f32],
    pert_row: &mut [f32],
    costs: &mut [f32],
    mode: KernelMode,
) {
    let stride = widest * n;
    let k_out = layers.last().unwrap().outputs;
    for (bp, bc) in probes.chunks(PROBE_BLOCK * p).zip(costs.chunks_mut(PROBE_BLOCK)) {
        let pb = bc.len();
        let mut cur: &mut [f32] = &mut acts_a[..];
        let mut nxt: &mut [f32] = &mut acts_b[..];
        let mut offset = 0usize;
        let mut neuron_base = 0usize;
        for (li, layer) in layers.iter().enumerate() {
            let width = layer.inputs;
            let n_out = layer.outputs;
            let wlen = width * n_out;
            if li == 0 {
                // The unperturbed θ part of layer 0 is the shared base.
                for q in 0..pb {
                    for s in 0..n {
                        nxt[q * stride + s * n_out..q * stride + (s + 1) * n_out]
                            .copy_from_slice(&base[s * n_out..(s + 1) * n_out]);
                    }
                }
            } else {
                let bias = &theta[offset + wlen..offset + wlen + n_out];
                for q in 0..pb {
                    for s in 0..n {
                        nxt[q * stride + s * n_out..q * stride + (s + 1) * n_out]
                            .copy_from_slice(bias);
                    }
                }
                // Batch-major θ walk: one weight-row load serves every
                // probe's rows in the block.
                for j0 in (0..n_out).step_by(COL_BLOCK) {
                    let jb = COL_BLOCK.min(n_out - j0);
                    for i in 0..width {
                        let w0 = offset + i * n_out + j0;
                        let wrow = &theta[w0..w0 + jb];
                        for q in 0..pb {
                            for s in 0..n {
                                let hv = cur[q * stride + s * width + i];
                                let z0 = q * stride + s * n_out + j0;
                                axpy(&mut nxt[z0..z0 + jb], wrow, hv, mode);
                            }
                        }
                    }
                }
            }
            // Per-probe θ̃ term + activation, in the scalar per-row order.
            for q in 0..pb {
                let tt = &bp[q * p..(q + 1) * p];
                for s in 0..n {
                    let h: &[f32] = if li == 0 {
                        &x[s * width..(s + 1) * width]
                    } else {
                        &cur[q * stride + s * width..q * stride + (s + 1) * width]
                    };
                    let prow = &mut pert_row[..n_out];
                    prow.copy_from_slice(&tt[offset + wlen..offset + wlen + n_out]);
                    for (i, &hv) in h.iter().enumerate() {
                        let trow = &tt[offset + i * n_out..offset + (i + 1) * n_out];
                        axpy(prow, trow, hv, mode);
                    }
                    let zrow = &mut nxt[q * stride + s * n_out..q * stride + (s + 1) * n_out];
                    for (z, &pv) in zrow.iter_mut().zip(prow.iter()) {
                        *z += pv;
                    }
                    activate_row(layer.activation, defects, neuron_base, zrow);
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
            offset += wlen + n_out;
            neuron_base += n_out;
        }
        for (q, c) in bc.iter_mut().enumerate() {
            *c = mse(&cur[q * stride..q * stride + n * k_out], y);
        }
    }
}

/// Apply one layer's activation to a sample's post-GEMM row, routing
/// through the defect table (`neuron_base` indexes the layer's first
/// neuron).
///
/// Sigmoid takes the [`NeuronDefects::activate`] generalized-logistic
/// path **verbatim** — with identity defects this is the plain sigmoid
/// the pre-refactor engine computed, bit for bit.  The other elementwise
/// activations use the same defect shape, `α·act(β(a − a₀)) + b`, and
/// softmax warps the pre-activations with β/a₀ before the (max-shifted,
/// numerically stable) row normalization, then scales the probabilities
/// with α/b.
#[inline]
pub fn activate_row(
    act: Activation,
    defects: &NeuronDefects,
    neuron_base: usize,
    zrow: &mut [f32],
) {
    match act {
        Activation::Sigmoid => {
            for (j, z) in zrow.iter_mut().enumerate() {
                *z = defects.activate(neuron_base + j, *z);
            }
        }
        Activation::Relu | Activation::Tanh | Activation::Identity => {
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                let a = defects.beta[k] * (*z - defects.offset_a[k]);
                let v = match act {
                    Activation::Relu => {
                        if a > 0.0 {
                            a
                        } else {
                            0.0
                        }
                    }
                    Activation::Tanh => a.tanh(),
                    _ => a,
                };
                *z = defects.alpha[k] * v + defects.offset_b[k];
            }
        }
        Activation::Softmax => {
            let mut mx = f32::NEG_INFINITY;
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                *z = defects.beta[k] * (*z - defects.offset_a[k]);
                if *z > mx {
                    mx = *z;
                }
            }
            let mut sum = 0f32;
            for z in zrow.iter_mut() {
                *z = (*z - mx).exp();
                sum += *z;
            }
            let inv = 1.0 / sum;
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                *z = defects.alpha[k] * (*z * inv) + defects.offset_b[k];
            }
        }
    }
}

/// Unperturbed layer-0 pre-activations `z₀[s][j] = b₀[j] + Σᵢ x[s][i]·W₀[i][j]`
/// — probe-independent, computed once per device call and shared by the
/// baseline and every probe of a [`super::HardwareDevice::cost_many`]
/// sweep (and reused unchanged by the forward-only serving path).
pub fn compute_layer0_base(layers: &[Dense], theta: &[f32], x: &[f32], n: usize, base: &mut [f32]) {
    let width = layers[0].inputs;
    let n_out = layers[0].outputs;
    let wlen = width * n_out;
    let bias = &theta[wlen..wlen + n_out];
    for s in 0..n {
        let h = &x[s * width..(s + 1) * width];
        let zrow = &mut base[s * n_out..(s + 1) * n_out];
        zrow.copy_from_slice(bias);
        for (i, &hv) in h.iter().enumerate() {
            let wrow = &theta[i * n_out..(i + 1) * n_out];
            for (z, &wv) in zrow.iter_mut().zip(wrow) {
                *z += hv * wv;
            }
        }
    }
}

/// Forward pass for one probe (or the baseline / a served inference when
/// `tilde` is `None`) over `n` samples, starting from the precomputed
/// layer-0 `base`.
///
/// Weight rows are walked in their natural `[i][j]` (row-major) layout —
/// contiguous axpy sweeps per input neuron — and the perturbation term
/// accumulates in its own row so the shared `base` stays bitwise
/// reusable across probes.  The per-layer θ offsets follow
/// [`crate::model::ModelSpec::param_layout`] (weights then biases, layer
/// by layer).
#[allow(clippy::too_many_arguments)]
pub fn forward_one(
    layers: &[Dense],
    theta: &[f32],
    defects: &NeuronDefects,
    x: &[f32],
    n: usize,
    base: &[f32],
    tilde: Option<&[f32]>,
    acts_a: &mut [f32],
    acts_b: &mut [f32],
    pert_row: &mut [f32],
    out: &mut [f32],
) {
    let mut acts_a = acts_a;
    let mut acts_b = acts_b;
    let mut offset = 0usize; // into theta / tilde
    let mut neuron_base = 0usize; // into the defect table
    for (li, layer) in layers.iter().enumerate() {
        let width = layer.inputs;
        let n_out = layer.outputs;
        let wlen = width * n_out;
        for s in 0..n {
            let h: &[f32] = if li == 0 {
                &x[s * width..(s + 1) * width]
            } else {
                &acts_a[s * width..(s + 1) * width]
            };
            let zrow = &mut acts_b[s * n_out..(s + 1) * n_out];
            if li == 0 {
                zrow.copy_from_slice(&base[s * n_out..(s + 1) * n_out]);
            } else {
                zrow.copy_from_slice(&theta[offset + wlen..offset + wlen + n_out]);
                for (i, &hv) in h.iter().enumerate() {
                    let wrow = &theta[offset + i * n_out..offset + (i + 1) * n_out];
                    for (z, &wv) in zrow.iter_mut().zip(wrow) {
                        *z += hv * wv;
                    }
                }
            }
            if let Some(tt) = tilde {
                let prow = &mut pert_row[..n_out];
                prow.copy_from_slice(&tt[offset + wlen..offset + wlen + n_out]);
                for (i, &hv) in h.iter().enumerate() {
                    let trow = &tt[offset + i * n_out..offset + (i + 1) * n_out];
                    for (pz, &tv) in prow.iter_mut().zip(trow) {
                        *pz += hv * tv;
                    }
                }
                for (z, &pv) in zrow.iter_mut().zip(prow.iter()) {
                    *z += pv;
                }
            }
            activate_row(layer.activation, defects, neuron_base, zrow);
        }
        std::mem::swap(&mut acts_a, &mut acts_b);
        offset += wlen + n_out;
        neuron_base += n_out;
    }
    let n_out = layers.last().unwrap().outputs;
    out.copy_from_slice(&acts_a[..n * n_out]);
}

/// Index of the row maximum with `Iterator::max_by` tie-breaking (the
/// **last** maximum wins on exact float equality) — the prediction rule
/// [`score_batch`] and the serving path's argmax reply both use.  One
/// function, one tie-break, everywhere.
///
/// Total on every input: the serving wire hands this untrusted floats,
/// and a `partial_cmp().unwrap()` here would let one NaN logit panic
/// the shared batcher thread (killing every session's requests) — or a
/// hostile `Evaluate` frame panic a training session.  NaN never beats
/// a finite value; an all-NaN row deterministically answers its last
/// index.  For NaN-free rows the result is identical to the `max_by`
/// rule, bit for bit.
pub fn argmax_row(v: &[f32]) -> usize {
    assert!(!v.is_empty(), "argmax of an empty row");
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x >= v[best] || v[best].is_nan() {
            best = i;
        }
    }
    best
}

/// Whether a prediction row matches its target row: `>0.5` threshold for
/// single-output networks, argmax agreement otherwise.
pub fn row_is_correct(yp: &[f32], yt: &[f32]) -> bool {
    if yp.len() == 1 {
        (yp[0] > 0.5) == (yt[0] > 0.5)
    } else {
        argmax_row(yp) == argmax_row(yt)
    }
}

/// The shared cost/accuracy head over a forward output block: MSE cost
/// plus the number of correctly-classified samples.  Every consumer of
/// "(cost, #correct)" — [`super::NativeDevice`]'s `evaluate`, the
/// trainer's accuracy probe, the serving client's scoring — goes through
/// this one function so train-time and serve-time accuracy can never
/// disagree on the rule.
pub fn score_batch(out: &[f32], y: &[f32], n: usize, k: usize) -> (f32, f32) {
    let cost = mse(out, y);
    let mut correct = 0f32;
    for s in 0..n {
        if row_is_correct(&out[s * k..(s + 1) * k], &y[s * k..(s + 1) * k]) {
            correct += 1.0;
        }
    }
    (cost, correct)
}

/// Persistent scratch for forward-only callers (the serving path and any
/// batched eval): activation ping-pong blocks, the layer-0 base, and the
/// (unused-when-unperturbed, but signature-required) perturbation row.
/// Grows only — after the first call at a given shape the forward path
/// never allocates.
#[derive(Debug, Default)]
pub struct ForwardScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    base: Vec<f32>,
    pert: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the buffers for `n` samples of a stack whose widest layer is
    /// `widest` neurons.
    fn ensure(&mut self, widest: usize, n: usize) {
        let stride = widest * n;
        if self.a.len() < stride {
            self.a.resize(stride, 0.0);
            self.b.resize(stride, 0.0);
            self.base.resize(stride, 0.0);
        }
        if self.pert.len() < widest {
            self.pert.resize(widest, 0.0);
        }
    }

    /// Unperturbed batched forward over `n` samples: `out` must hold
    /// exactly `n · layers.last().outputs` floats on return (it is
    /// resized here).  Identical arithmetic, in identical order, to the
    /// training path's baseline measurement for the same θ.
    pub fn forward(
        &mut self,
        layers: &[Dense],
        widest: usize,
        theta: &[f32],
        defects: &NeuronDefects,
        x: &[f32],
        n: usize,
        out: &mut Vec<f32>,
    ) {
        self.ensure(widest, n);
        rows_total().add(n as u64);
        let stride = widest * n;
        let k = layers.last().unwrap().outputs;
        out.resize(n * k, 0.0);
        let mode = kernel_mode();
        if mode != KernelMode::Scalar {
            forward_blocked(
                layers,
                theta,
                defects,
                x,
                n,
                &mut self.a[..stride],
                &mut self.b[..stride],
                &mut out[..n * k],
                mode,
            );
            return;
        }
        let base_len = n * layers[0].outputs;
        compute_layer0_base(layers, theta, x, n, &mut self.base[..base_len]);
        forward_one(
            layers,
            theta,
            defects,
            x,
            n,
            &self.base[..base_len],
            None,
            &mut self.a[..stride],
            &mut self.b[..stride],
            &mut self.pert[..widest],
            &mut out[..n * k],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_breaks_ties_like_max_by() {
        // Iterator::max_by returns the LAST maximal element; the shared
        // argmax must match it exactly or served predictions drift from
        // evaluate() on tied logits.
        assert_eq!(argmax_row(&[0.5, 0.5]), 1);
        assert_eq!(argmax_row(&[1.0, 0.5, 1.0, 0.2]), 2);
        assert_eq!(argmax_row(&[3.0]), 0);
    }

    #[test]
    fn argmax_is_total_on_hostile_floats() {
        // Untrusted wire input: NaN must neither panic nor outrank a
        // finite logit (a panic here used to be a one-request DoS on the
        // shared batcher thread).
        assert_eq!(argmax_row(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax_row(&[0.5, f32::NAN, 1.0]), 2);
        assert_eq!(argmax_row(&[1.0, f32::NAN]), 0);
        assert_eq!(argmax_row(&[f32::NAN, f32::NAN]), 1, "all-NaN row answers deterministically");
        assert_eq!(argmax_row(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
    }

    #[test]
    fn score_batch_rules() {
        // Single-output: >0.5 threshold on both sides.
        let (cost, correct) = score_batch(&[0.6, 0.4], &[1.0, 1.0], 2, 1);
        assert!(cost > 0.0);
        assert_eq!(correct, 1.0);
        // Multi-output: argmax agreement.
        let out = [0.1, 0.9, 0.8, 0.2];
        let y = [0.0, 1.0, 0.0, 1.0];
        let (_, correct) = score_batch(&out, &y, 2, 2);
        assert_eq!(correct, 1.0);
    }

    #[test]
    fn forward_scratch_matches_hand_sigmoid() {
        use crate::model::ModelSpec;
        let spec: ModelSpec = "2x2x1".parse().unwrap();
        let theta = [1.0f32, 2.0, 3.0, 4.0, 0.5, -0.5, 1.0, -1.0, 0.25];
        let defects = NeuronDefects::identity(spec.n_neurons());
        let mut scratch = ForwardScratch::new();
        let mut out = Vec::new();
        scratch.forward(spec.layers(), spec.widest(), &theta, &defects, &[1.0, 0.5], 1, &mut out);
        let sig = |z: f32| 1.0 / (1.0 + (-z).exp());
        let h0 = sig(1.0 + 0.5 * 3.0 + 0.5);
        let h1 = sig(2.0 + 0.5 * 4.0 - 0.5);
        let want = sig(h0 - h1 + 0.25);
        assert!((out[0] - want).abs() < 1e-6, "got {}, want {want}", out[0]);
    }

    #[test]
    fn zero_sample_forward_is_a_no_op() {
        use crate::model::ModelSpec;
        let spec: ModelSpec = "3x2x2:relu,softmax".parse().unwrap();
        let theta = vec![0.1f32; spec.param_count()];
        let defects = NeuronDefects::identity(spec.n_neurons());
        let mut scratch = ForwardScratch::new();
        let mut out = vec![9.0f32; 4];
        scratch.forward(spec.layers(), spec.widest(), &theta, &defects, &[], 0, &mut out);
        assert!(out.is_empty(), "n = 0 must produce an empty output block");
    }

    #[test]
    fn kernel_mode_parse_roundtrips() {
        for mode in [KernelMode::Scalar, KernelMode::Blocked, KernelMode::Simd] {
            assert_eq!(KernelMode::parse(mode.as_str()), Some(mode));
        }
        assert_eq!(KernelMode::parse("avx512-hopes-and-dreams"), None);
    }

    #[test]
    fn axpy_matches_scalar_bitwise_at_awkward_lengths() {
        // Lengths straddling every lane boundary (SSE 4, AVX/portable 8),
        // with values whose products exercise real rounding.
        for len in [1usize, 3, 4, 7, 8, 9, 15, 16, 17, 31, 33] {
            let row: Vec<f32> = (0..len).map(|i| (i as f32 * 0.7 - 3.1) / 1.3).collect();
            let init: Vec<f32> = (0..len).map(|i| (i as f32 * 1.9 + 0.2) / 0.7).collect();
            let h = 0.123456f32;
            let mut want = init.clone();
            for (z, &wv) in want.iter_mut().zip(&row) {
                *z += h * wv;
            }
            for mode in [KernelMode::Scalar, KernelMode::Blocked, KernelMode::Simd] {
                let mut acc = init.clone();
                axpy(&mut acc, &row, h, mode);
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&acc), bits(&want), "mode {mode:?} len {len}");
            }
        }
    }

    #[test]
    fn blocked_forward_is_bit_identical_to_scalar_forward() {
        use crate::model::ModelSpec;
        use crate::rng::Rng;
        // Wider than COL_BLOCK would matter only at huge layers; the
        // point here is crossing SAMPLE_BLOCK and lane boundaries with a
        // mixed-activation stack.
        let spec: ModelSpec = "7x13x9x3:relu,tanh,softmax".parse().unwrap();
        let mut rng = Rng::new(41);
        let mut theta = vec![0f32; spec.param_count()];
        rng.fill_uniform(&mut theta, -1.0, 1.0);
        let defects = NeuronDefects::identity(spec.n_neurons());
        let n = 11usize; // not a multiple of SAMPLE_BLOCK
        let mut x = vec![0f32; n * 7];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let widest = spec.widest();
        let stride = widest * n;
        let (mut a, mut b) = (vec![0f32; stride], vec![0f32; stride]);
        let mut base = vec![0f32; stride];
        let mut pert = vec![0f32; widest];
        let mut want = vec![0f32; n * 3];
        let base_len = n * spec.layers()[0].outputs;
        compute_layer0_base(spec.layers(), &theta, &x, n, &mut base[..base_len]);
        forward_one(
            spec.layers(),
            &theta,
            &defects,
            &x,
            n,
            &base[..base_len],
            None,
            &mut a,
            &mut b,
            &mut pert,
            &mut want,
        );
        for mode in [KernelMode::Blocked, KernelMode::Simd] {
            let mut got = vec![0f32; n * 3];
            forward_blocked(spec.layers(), &theta, &defects, &x, n, &mut a, &mut b, &mut got, mode);
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "mode {mode:?}");
        }
    }

    #[test]
    fn probe_block_sweep_is_bit_identical_to_serial_probes() {
        use crate::model::ModelSpec;
        use crate::rng::Rng;
        let spec: ModelSpec = "5x9x6x2:relu,sigmoid,softmax".parse().unwrap();
        let p = spec.param_count();
        let mut rng = Rng::new(43);
        let mut theta = vec![0f32; p];
        rng.fill_uniform(&mut theta, -1.0, 1.0);
        let defects = NeuronDefects::identity(spec.n_neurons());
        let n = 3usize;
        let mut x = vec![0f32; n * 5];
        let mut y = vec![0f32; n * 2];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        rng.fill_uniform(&mut y, 0.0, 1.0);
        // k deliberately not a multiple of PROBE_BLOCK (tail block).
        let k = PROBE_BLOCK + 3;
        let mut probes = vec![0f32; k * p];
        rng.fill_uniform(&mut probes, -0.05, 0.05);
        let widest = spec.widest();
        let stride = widest * n;
        let mut base = vec![0f32; stride];
        let base_len = n * spec.layers()[0].outputs;
        compute_layer0_base(spec.layers(), &theta, &x, n, &mut base[..base_len]);
        // Serial scalar reference.
        let (mut a, mut b) = (vec![0f32; stride], vec![0f32; stride]);
        let mut pert = vec![0f32; widest];
        let mut out = vec![0f32; n * 2];
        let mut want = vec![0f32; k];
        for (tt, c) in probes.chunks(p).zip(want.iter_mut()) {
            forward_one(
                spec.layers(),
                &theta,
                &defects,
                &x,
                n,
                &base[..base_len],
                Some(tt),
                &mut a,
                &mut b,
                &mut pert,
                &mut out,
            );
            *c = mse(&out, &y);
        }
        for mode in [KernelMode::Blocked, KernelMode::Simd] {
            let mut ba = vec![0f32; PROBE_BLOCK * stride];
            let mut bb = vec![0f32; PROBE_BLOCK * stride];
            let mut got = vec![0f32; k];
            sweep_probe_block(
                spec.layers(),
                &theta,
                &defects,
                &x,
                n,
                &base[..base_len],
                &probes,
                p,
                &y,
                widest,
                &mut ba,
                &mut bb,
                &mut pert,
                &mut got,
                mode,
            );
            let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&got), bits(&want), "mode {mode:?}");
        }
    }
}
