//! The shared layer-sweep executor: one set of forward kernels for the
//! training and inference paths.
//!
//! Before the serving subsystem existed these functions lived inside
//! [`super::NativeDevice`].  Serving needs the *same arithmetic* — a
//! checkpoint trained on the device must answer queries with exactly the
//! activations the trainer measured, or an accuracy number printed at
//! train time silently disagrees with the accuracy the served model
//! delivers.  Factoring the kernels here makes that a property of the
//! code shape instead of a test assertion: [`super::NativeDevice`] (the
//! training path) and [`crate::serve::InferenceEngine`] (the forward-only
//! serving path) call the **identical functions**, so their outputs are
//! bit-identical for the same θ by construction.  The regression pin
//! lives in `rust/tests/integration_serve.rs`.
//!
//! The split mirrors the multi-probe cost engine's two phases:
//!
//! - [`compute_layer0_base`] — the unperturbed first-layer
//!   pre-activations, probe-independent, computed once per device call;
//! - [`forward_one`] — the remaining walk for one probe (or the
//!   baseline / an inference pass when `tilde` is `None`).
//!
//! [`score_batch`] is the shared cost/accuracy head: the MSE cost plus
//! the prediction rule (`>0.5` for single-output networks, row argmax
//! otherwise) that [`super::HardwareDevice::evaluate`], the trainer's
//! accuracy probe and the serving path must all agree on — including the
//! tie-breaking of [`argmax_row`], which follows `Iterator::max_by`
//! (last maximum wins on exact ties).

use crate::model::{Activation, Dense};
use crate::noise::NeuronDefects;
use crate::obs;

/// Rows pushed through [`ForwardScratch::forward`], counted once per
/// batched call (never inside the layer kernels themselves).
fn rows_total() -> &'static obs::Counter {
    static M: std::sync::OnceLock<obs::Counter> = std::sync::OnceLock::new();
    M.get_or_init(|| obs::counter("mgd_exec_rows_total"))
}

/// Mean-squared error between a prediction block and its targets.
pub fn mse(y_pred: &[f32], y_true: &[f32]) -> f32 {
    debug_assert_eq!(y_pred.len(), y_true.len());
    let sum: f32 = y_pred
        .iter()
        .zip(y_true)
        .map(|(p, t)| {
            let d = p - t;
            d * d
        })
        .sum();
    sum / y_pred.len() as f32
}

/// Apply one layer's activation to a sample's post-GEMM row, routing
/// through the defect table (`neuron_base` indexes the layer's first
/// neuron).
///
/// Sigmoid takes the [`NeuronDefects::activate`] generalized-logistic
/// path **verbatim** — with identity defects this is the plain sigmoid
/// the pre-refactor engine computed, bit for bit.  The other elementwise
/// activations use the same defect shape, `α·act(β(a − a₀)) + b`, and
/// softmax warps the pre-activations with β/a₀ before the (max-shifted,
/// numerically stable) row normalization, then scales the probabilities
/// with α/b.
#[inline]
pub fn activate_row(
    act: Activation,
    defects: &NeuronDefects,
    neuron_base: usize,
    zrow: &mut [f32],
) {
    match act {
        Activation::Sigmoid => {
            for (j, z) in zrow.iter_mut().enumerate() {
                *z = defects.activate(neuron_base + j, *z);
            }
        }
        Activation::Relu | Activation::Tanh | Activation::Identity => {
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                let a = defects.beta[k] * (*z - defects.offset_a[k]);
                let v = match act {
                    Activation::Relu => {
                        if a > 0.0 {
                            a
                        } else {
                            0.0
                        }
                    }
                    Activation::Tanh => a.tanh(),
                    _ => a,
                };
                *z = defects.alpha[k] * v + defects.offset_b[k];
            }
        }
        Activation::Softmax => {
            let mut mx = f32::NEG_INFINITY;
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                *z = defects.beta[k] * (*z - defects.offset_a[k]);
                if *z > mx {
                    mx = *z;
                }
            }
            let mut sum = 0f32;
            for z in zrow.iter_mut() {
                *z = (*z - mx).exp();
                sum += *z;
            }
            let inv = 1.0 / sum;
            for (j, z) in zrow.iter_mut().enumerate() {
                let k = neuron_base + j;
                *z = defects.alpha[k] * (*z * inv) + defects.offset_b[k];
            }
        }
    }
}

/// Unperturbed layer-0 pre-activations `z₀[s][j] = b₀[j] + Σᵢ x[s][i]·W₀[i][j]`
/// — probe-independent, computed once per device call and shared by the
/// baseline and every probe of a [`super::HardwareDevice::cost_many`]
/// sweep (and reused unchanged by the forward-only serving path).
pub fn compute_layer0_base(layers: &[Dense], theta: &[f32], x: &[f32], n: usize, base: &mut [f32]) {
    let width = layers[0].inputs;
    let n_out = layers[0].outputs;
    let wlen = width * n_out;
    let bias = &theta[wlen..wlen + n_out];
    for s in 0..n {
        let h = &x[s * width..(s + 1) * width];
        let zrow = &mut base[s * n_out..(s + 1) * n_out];
        zrow.copy_from_slice(bias);
        for (i, &hv) in h.iter().enumerate() {
            let wrow = &theta[i * n_out..(i + 1) * n_out];
            for (z, &wv) in zrow.iter_mut().zip(wrow) {
                *z += hv * wv;
            }
        }
    }
}

/// Forward pass for one probe (or the baseline / a served inference when
/// `tilde` is `None`) over `n` samples, starting from the precomputed
/// layer-0 `base`.
///
/// Weight rows are walked in their natural `[i][j]` (row-major) layout —
/// contiguous axpy sweeps per input neuron — and the perturbation term
/// accumulates in its own row so the shared `base` stays bitwise
/// reusable across probes.  The per-layer θ offsets follow
/// [`crate::model::ModelSpec::param_layout`] (weights then biases, layer
/// by layer).
#[allow(clippy::too_many_arguments)]
pub fn forward_one(
    layers: &[Dense],
    theta: &[f32],
    defects: &NeuronDefects,
    x: &[f32],
    n: usize,
    base: &[f32],
    tilde: Option<&[f32]>,
    acts_a: &mut [f32],
    acts_b: &mut [f32],
    pert_row: &mut [f32],
    out: &mut [f32],
) {
    let mut acts_a = acts_a;
    let mut acts_b = acts_b;
    let mut offset = 0usize; // into theta / tilde
    let mut neuron_base = 0usize; // into the defect table
    for (li, layer) in layers.iter().enumerate() {
        let width = layer.inputs;
        let n_out = layer.outputs;
        let wlen = width * n_out;
        for s in 0..n {
            let h: &[f32] = if li == 0 {
                &x[s * width..(s + 1) * width]
            } else {
                &acts_a[s * width..(s + 1) * width]
            };
            let zrow = &mut acts_b[s * n_out..(s + 1) * n_out];
            if li == 0 {
                zrow.copy_from_slice(&base[s * n_out..(s + 1) * n_out]);
            } else {
                zrow.copy_from_slice(&theta[offset + wlen..offset + wlen + n_out]);
                for (i, &hv) in h.iter().enumerate() {
                    let wrow = &theta[offset + i * n_out..offset + (i + 1) * n_out];
                    for (z, &wv) in zrow.iter_mut().zip(wrow) {
                        *z += hv * wv;
                    }
                }
            }
            if let Some(tt) = tilde {
                let prow = &mut pert_row[..n_out];
                prow.copy_from_slice(&tt[offset + wlen..offset + wlen + n_out]);
                for (i, &hv) in h.iter().enumerate() {
                    let trow = &tt[offset + i * n_out..offset + (i + 1) * n_out];
                    for (pz, &tv) in prow.iter_mut().zip(trow) {
                        *pz += hv * tv;
                    }
                }
                for (z, &pv) in zrow.iter_mut().zip(prow.iter()) {
                    *z += pv;
                }
            }
            activate_row(layer.activation, defects, neuron_base, zrow);
        }
        std::mem::swap(&mut acts_a, &mut acts_b);
        offset += wlen + n_out;
        neuron_base += n_out;
    }
    let n_out = layers.last().unwrap().outputs;
    out.copy_from_slice(&acts_a[..n * n_out]);
}

/// Index of the row maximum with `Iterator::max_by` tie-breaking (the
/// **last** maximum wins on exact float equality) — the prediction rule
/// [`score_batch`] and the serving path's argmax reply both use.  One
/// function, one tie-break, everywhere.
///
/// Total on every input: the serving wire hands this untrusted floats,
/// and a `partial_cmp().unwrap()` here would let one NaN logit panic
/// the shared batcher thread (killing every session's requests) — or a
/// hostile `Evaluate` frame panic a training session.  NaN never beats
/// a finite value; an all-NaN row deterministically answers its last
/// index.  For NaN-free rows the result is identical to the `max_by`
/// rule, bit for bit.
pub fn argmax_row(v: &[f32]) -> usize {
    assert!(!v.is_empty(), "argmax of an empty row");
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate().skip(1) {
        if x >= v[best] || v[best].is_nan() {
            best = i;
        }
    }
    best
}

/// Whether a prediction row matches its target row: `>0.5` threshold for
/// single-output networks, argmax agreement otherwise.
pub fn row_is_correct(yp: &[f32], yt: &[f32]) -> bool {
    if yp.len() == 1 {
        (yp[0] > 0.5) == (yt[0] > 0.5)
    } else {
        argmax_row(yp) == argmax_row(yt)
    }
}

/// The shared cost/accuracy head over a forward output block: MSE cost
/// plus the number of correctly-classified samples.  Every consumer of
/// "(cost, #correct)" — [`super::NativeDevice`]'s `evaluate`, the
/// trainer's accuracy probe, the serving client's scoring — goes through
/// this one function so train-time and serve-time accuracy can never
/// disagree on the rule.
pub fn score_batch(out: &[f32], y: &[f32], n: usize, k: usize) -> (f32, f32) {
    let cost = mse(out, y);
    let mut correct = 0f32;
    for s in 0..n {
        if row_is_correct(&out[s * k..(s + 1) * k], &y[s * k..(s + 1) * k]) {
            correct += 1.0;
        }
    }
    (cost, correct)
}

/// Persistent scratch for forward-only callers (the serving path and any
/// batched eval): activation ping-pong blocks, the layer-0 base, and the
/// (unused-when-unperturbed, but signature-required) perturbation row.
/// Grows only — after the first call at a given shape the forward path
/// never allocates.
#[derive(Debug, Default)]
pub struct ForwardScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    base: Vec<f32>,
    pert: Vec<f32>,
}

impl ForwardScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Grow the buffers for `n` samples of a stack whose widest layer is
    /// `widest` neurons.
    fn ensure(&mut self, widest: usize, n: usize) {
        let stride = widest * n;
        if self.a.len() < stride {
            self.a.resize(stride, 0.0);
            self.b.resize(stride, 0.0);
            self.base.resize(stride, 0.0);
        }
        if self.pert.len() < widest {
            self.pert.resize(widest, 0.0);
        }
    }

    /// Unperturbed batched forward over `n` samples: `out` must hold
    /// exactly `n · layers.last().outputs` floats on return (it is
    /// resized here).  Identical arithmetic, in identical order, to the
    /// training path's baseline measurement for the same θ.
    pub fn forward(
        &mut self,
        layers: &[Dense],
        widest: usize,
        theta: &[f32],
        defects: &NeuronDefects,
        x: &[f32],
        n: usize,
        out: &mut Vec<f32>,
    ) {
        self.ensure(widest, n);
        rows_total().add(n as u64);
        let stride = widest * n;
        let k = layers.last().unwrap().outputs;
        out.resize(n * k, 0.0);
        let base_len = n * layers[0].outputs;
        compute_layer0_base(layers, theta, x, n, &mut self.base[..base_len]);
        forward_one(
            layers,
            theta,
            defects,
            x,
            n,
            &self.base[..base_len],
            None,
            &mut self.a[..stride],
            &mut self.b[..stride],
            &mut self.pert[..widest],
            &mut out[..n * k],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_breaks_ties_like_max_by() {
        // Iterator::max_by returns the LAST maximal element; the shared
        // argmax must match it exactly or served predictions drift from
        // evaluate() on tied logits.
        assert_eq!(argmax_row(&[0.5, 0.5]), 1);
        assert_eq!(argmax_row(&[1.0, 0.5, 1.0, 0.2]), 2);
        assert_eq!(argmax_row(&[3.0]), 0);
    }

    #[test]
    fn argmax_is_total_on_hostile_floats() {
        // Untrusted wire input: NaN must neither panic nor outrank a
        // finite logit (a panic here used to be a one-request DoS on the
        // shared batcher thread).
        assert_eq!(argmax_row(&[f32::NAN, 1.0, 0.5]), 1);
        assert_eq!(argmax_row(&[0.5, f32::NAN, 1.0]), 2);
        assert_eq!(argmax_row(&[1.0, f32::NAN]), 0);
        assert_eq!(argmax_row(&[f32::NAN, f32::NAN]), 1, "all-NaN row answers deterministically");
        assert_eq!(argmax_row(&[f32::NEG_INFINITY, f32::INFINITY]), 1);
    }

    #[test]
    fn score_batch_rules() {
        // Single-output: >0.5 threshold on both sides.
        let (cost, correct) = score_batch(&[0.6, 0.4], &[1.0, 1.0], 2, 1);
        assert!(cost > 0.0);
        assert_eq!(correct, 1.0);
        // Multi-output: argmax agreement.
        let out = [0.1, 0.9, 0.8, 0.2];
        let y = [0.0, 1.0, 0.0, 1.0];
        let (_, correct) = score_batch(&out, &y, 2, 2);
        assert_eq!(correct, 1.0);
    }

    #[test]
    fn forward_scratch_matches_hand_sigmoid() {
        use crate::model::ModelSpec;
        let spec: ModelSpec = "2x2x1".parse().unwrap();
        let theta = [1.0f32, 2.0, 3.0, 4.0, 0.5, -0.5, 1.0, -1.0, 0.25];
        let defects = NeuronDefects::identity(spec.n_neurons());
        let mut scratch = ForwardScratch::new();
        let mut out = Vec::new();
        scratch.forward(spec.layers(), spec.widest(), &theta, &defects, &[1.0, 0.5], 1, &mut out);
        let sig = |z: f32| 1.0 / (1.0 + (-z).exp());
        let h0 = sig(1.0 + 0.5 * 3.0 + 0.5);
        let h1 = sig(2.0 + 0.5 * 4.0 - 0.5);
        let want = sig(h0 - h1 + 0.25);
        assert!((out[0] - want).abs() < 1e-6, "got {}, want {want}", out[0]);
    }

    #[test]
    fn zero_sample_forward_is_a_no_op() {
        use crate::model::ModelSpec;
        let spec: ModelSpec = "3x2x2:relu,softmax".parse().unwrap();
        let theta = vec![0.1f32; spec.param_count()];
        let defects = NeuronDefects::identity(spec.n_neurons());
        let mut scratch = ForwardScratch::new();
        let mut out = vec![9.0f32; 4];
        scratch.forward(spec.layers(), spec.widest(), &theta, &defects, &[], 0, &mut out);
        assert!(out.is_empty(), "n = 0 must produce an empty output block");
    }
}
