//! Fig. 4 — MGD ≡ backpropagation in the long-integration limit.
//!
//! XOR on a 2-2-1 network (9 parameters), batch ratio τθ/τx = 1:
//!
//! - MGD with τθ = τx = 1000: the gradient estimate per sample is nearly
//!   exact → the cost-vs-**epoch** trajectory tracks backprop (panel a).
//! - MGD with τθ = τx = 1: poor per-sample estimate → many more epochs,
//!   but *fewer total timesteps* (panel b) — the paper's data-efficiency
//!   vs wall-clock trade.
//! - Backprop (SGD batch 1, same η schedule) as the dashed reference,
//!   running on the `gradtrain` AOT artifact.
//!
//! Output: `results/fig4.csv` — series, epoch, steps, mean cost over
//! replicas (paper: 1000 random inits; scaled by `--scale`).

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::{replica_stats, MgdConfig, MgdTrainer, ScheduleKind, TrainOptions};
use crate::datasets::xor;
use crate::metrics::CsvWriter;
use crate::optim::{init_params_uniform, BackpropTrainer};
use crate::perturb::PerturbKind;
use crate::rng::Rng;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct Fig4Config {
    pub replicas: usize,
    pub epochs: u64,
    pub eta: f32,
    pub amplitude: f32,
    pub tau_long: u64,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config { replicas: 40, epochs: 400, eta: 1.0, amplitude: 0.02, tau_long: 1000 }
    }
}

impl Fig4Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig4Config::default();
        let o = ctx.overrides("fig4")?;
        Ok(Fig4Config {
            replicas: o.usize("replicas", d.replicas)?,
            epochs: o.u64("epochs", d.epochs)?,
            eta: o.f32("eta", d.eta)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            tau_long: o.u64("tau_long", d.tau_long)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig4Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 4) as usize;
    let epochs = ctx.scaled(cfg.epochs, 20);
    let data = xor();
    let epoch_steps_short = data.n as u64; // τθ=1: 4 steps per epoch
    let epoch_steps_long = data.n as u64 * cfg.tau_long; // τθ=1000

    let mut csv = CsvWriter::create(
        ctx.result_path("fig4.csv"),
        &["series", "epoch", "steps", "mean_cost"],
    )?;

    // --- MGD, τθ = τx ∈ {1, tau_long} ------------------------------------
    for (series, tau) in [("mgd_tau1", 1u64), ("mgd_tau1000", cfg.tau_long)] {
        let epochs_this = if tau == 1 { epochs } else { epochs.min(120) };
        // Per-replica cost trajectory, sampled once per epoch.
        let trajectories: Vec<Vec<f32>> = {
            let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
                let mut dev = native_mlp(&[2, 2, 1], 1, seed)?;
                let mcfg = MgdConfig {
                    tau_x: tau,
                    tau_theta: tau,
                    tau_p: 1,
                    eta: cfg.eta,
                    amplitude: cfg.amplitude,
                    kind: PerturbKind::RademacherCode,
                    seed,
                    ..Default::default()
                };
                let mut tr = MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
                let opts = TrainOptions {
                    max_steps: epochs_this * data.n as u64 * tau,
                    eval_every: data.n as u64 * tau, // once per epoch
                    ..Default::default()
                };
                tr.train(&opts, None)
            })?;
            outcomes
                .into_iter()
                .map(|o| o.result.eval_trace.iter().map(|&(_, c, _)| c).collect())
                .collect()
        };
        let per_epoch = epochs_this as usize;
        let steps_per_epoch = if tau == 1 { epoch_steps_short } else { epoch_steps_long };
        for e in 0..per_epoch {
            let costs: Vec<f32> =
                trajectories.iter().filter_map(|t| t.get(e).copied()).collect();
            if costs.is_empty() {
                break;
            }
            let mean = costs.iter().sum::<f32>() / costs.len() as f32;
            csv.row(&[
                series.to_string(),
                (e + 1).to_string(),
                ((e as u64 + 1) * steps_per_epoch).to_string(),
                format!("{mean:.6}"),
            ])?;
        }
        println!(
            "fig4: {series}: {replicas} replicas x {epochs_this} epochs (tau_theta = tau_x = {tau})"
        );
    }

    // --- Backprop reference (PJRT gradtrain artifact, SGD batch 1) --------
    {
        let rt = Runtime::new(&ctx.artifact_dir)?;
        let mut mean_costs = vec![0f64; epochs as usize];
        let mut counts = vec![0usize; epochs as usize];
        for r in 0..replicas.min(16) {
            let seed = ctx.seed + r as u64;
            let mut rng = Rng::new(seed ^ 0x494e_4954);
            let mut theta = vec![0f32; 9];
            init_params_uniform(&mut rng, &mut theta, 1.0);
            let mut tr = BackpropTrainer::new(&rt, "xor221", &data, theta, cfg.eta, seed)?;
            let opts = TrainOptions {
                max_steps: epochs * data.n as u64,
                eval_every: data.n as u64,
                ..Default::default()
            };
            let res = tr.train(&opts, None)?;
            for (e, &(_, c, _)) in res.eval_trace.iter().enumerate() {
                if e < mean_costs.len() {
                    mean_costs[e] += c as f64;
                    counts[e] += 1;
                }
            }
        }
        for e in 0..epochs as usize {
            if counts[e] == 0 {
                break;
            }
            csv.row(&[
                "backprop".to_string(),
                (e + 1).to_string(),
                ((e as u64 + 1) * epoch_steps_short).to_string(),
                format!("{:.6}", mean_costs[e] / counts[e] as f64),
            ])?;
        }
        println!("fig4: backprop reference via PJRT gradtrain artifact");
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig4.csv").display());
    Ok(())
}
