//! Table 3 — projected wall-clock training time on candidate hardware.
//!
//! The paper's Table 3 is an analytic projection: given the step counts
//! that reach a target accuracy (Table 2) and plausible hardware time
//! constants (τx, τp, τθ) from the literature, the wall-clock time is
//!
//! ```text
//! T = 2*steps * max(τp, τx)  +  (steps / τθ_steps) * τθ_write
//! ```
//!
//! simplified in the paper to `2*steps*τp` since τp dominates for HW1–3.
//! We regenerate it from (a) the paper's canonical step counts and (b)
//! the backprop comparator measured *on this machine* via the PJRT
//! gradtrain artifacts, so the final column is a real measurement.
//!
//! Output: `results/table3.csv`.

use std::time::Instant;

use anyhow::Result;

use crate::config::RunContext;
use crate::datasets::{parity, synthetic_cifar, synthetic_fmnist};
use crate::metrics::CsvWriter;
use crate::optim::{init_params, BackpropTrainer};
use crate::rng::Rng;
use crate::runtime::Runtime;

/// Hardware profile: MGD time constants (seconds).
struct Hw {
    name: &'static str,
    tau_x: f64,
    tau_p: f64,
    tau_theta: f64,
    examples: &'static str,
}

const HARDWARE: [Hw; 3] = [
    Hw {
        name: "HW1",
        tau_x: 100e-9,
        tau_p: 1e-3,
        tau_theta: 1e-3,
        examples: "chip-in-the-loop, photonics w/ thermo-optic tuning",
    },
    Hw {
        name: "HW2",
        tau_x: 1e-9,
        tau_p: 10e-9,
        tau_theta: 1e-6,
        examples: "mem-compute devices, analog VLSI",
    },
    Hw {
        name: "HW3",
        tau_x: 10e-12,
        tau_p: 200e-12,
        tau_theta: 200e-12,
        examples: "superconducting devices, athermal photonic modulators",
    },
];

/// Benchmark task: paper step count + our backprop measurement setup.
struct Task {
    name: &'static str,
    model: &'static str,
    /// The paper's canonical MGD step count for this task (Table 3).
    paper_steps: f64,
    /// Backprop steps needed on this testbed (measured batch-steps).
    bp_steps: u64,
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let rt = Runtime::new(&ctx.artifact_dir)?;
    let tasks = [
        Task { name: "2-bit parity (1e4 steps)", model: "xor221", paper_steps: 1e4, bp_steps: 2000 },
        Task {
            name: "Fashion-MNIST (1e6 steps)",
            model: "fmnist_cnn",
            paper_steps: 1e6,
            bp_steps: 200,
        },
        Task { name: "CIFAR-10 (1e7 steps)", model: "cifar_cnn", paper_steps: 1e7, bp_steps: 100 },
    ];

    let mut csv = CsvWriter::create(
        ctx.result_path("table3.csv"),
        &["task", "hw", "tau_x_s", "tau_p_s", "tau_theta_s", "mgd_time_s", "backprop_time_s"],
    )?;

    println!("{:<28} {:>12} {:>12} {:>12} {:>16}", "task", "HW1", "HW2", "HW3", "backprop(here)");
    for task in &tasks {
        // Measure backprop step time on this machine (PJRT artifact).
        let meta = rt.manifest.model(task.model)?.clone();
        let dataset = match task.model {
            "xor221" => parity(2),
            "fmnist_cnn" => synthetic_fmnist(1024, ctx.seed),
            "cifar_cnn" => synthetic_cifar(512, ctx.seed),
            _ => unreachable!(),
        };
        let mut rng = Rng::new(ctx.seed);
        let mut theta = vec![0f32; meta.param_count];
        init_params(&mut rng, &meta.tensors, &mut theta);
        let mut bp = BackpropTrainer::new(&rt, task.model, &dataset, theta, 0.1, ctx.seed)?;
        // Warm up, then time a fixed number of steps.
        bp.step()?;
        let timed_steps = 20u64;
        let t0 = Instant::now();
        for _ in 0..timed_steps {
            bp.step()?;
        }
        let per_step = t0.elapsed().as_secs_f64() / timed_steps as f64;
        let bp_time = per_step * task.bp_steps as f64;

        let mut row_times = Vec::new();
        for hw in &HARDWARE {
            // One MGD timestep costs 2 inferences (baseline C₀ +
            // perturbed C) gated by max(τp, τx); this factor-2 reproduces
            // the paper's Table 3 values exactly (20 s / 33 min / 5.6 h
            // for HW1).  Parameter writes add (steps/τθ_ratio)·τθ when
            // slower than the perturbation clock.
            let step_time = hw.tau_p.max(hw.tau_x);
            let updates = task.paper_steps; // τθ = 1 step in Table 2 rows
            let write_time = if hw.tau_theta > hw.tau_p {
                updates * (hw.tau_theta - hw.tau_p)
            } else {
                0.0
            };
            let total = 2.0 * task.paper_steps * step_time + write_time;
            row_times.push(total);
            csv.row(&[
                task.name.into(),
                hw.name.into(),
                format!("{:.3e}", hw.tau_x),
                format!("{:.3e}", hw.tau_p),
                format!("{:.3e}", hw.tau_theta),
                format!("{total:.6e}"),
                format!("{bp_time:.4e}"),
            ])?;
        }
        println!(
            "{:<28} {:>12} {:>12} {:>12} {:>16}",
            task.name,
            human_time(row_times[0]),
            human_time(row_times[1]),
            human_time(row_times[2]),
            human_time(bp_time),
        );
    }
    println!("\nhardware profiles:");
    for hw in &HARDWARE {
        println!(
            "  {}: tau_x={:.0e}s tau_p={:.0e}s tau_theta={:.0e}s  ({})",
            hw.name, hw.tau_x, hw.tau_p, hw.tau_theta, hw.examples
        );
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("table3.csv").display());
    Ok(())
}

/// Render seconds with the paper's unit style (µs / ms / s / min / h).
pub fn human_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.1} s", secs)
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.1} h", secs / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(4e-6), "4.0 us");
        assert_eq!(human_time(0.02), "20.0 ms");
        assert_eq!(human_time(20.0), "20.0 s");
        assert_eq!(human_time(2000.0), "33.3 min");
        assert_eq!(human_time(20_000.0), "5.6 h");
    }

    #[test]
    fn hw_profiles_match_paper_projection() {
        // Paper Table 3: 2-bit parity at 1e4 steps → HW1 ≈ 20 s (1 ms·1e4 + writes),
        // HW2 ≈ 200 µs (10 ns·1e4 + 1 µs updates ...), HW3 ≈ 4 µs.
        let steps = 1e4;
        let hw1 = steps * HARDWARE[0].tau_p;
        assert!((hw1 - 10.0).abs() < 11.0, "HW1 ~10-20s, got {hw1}");
        let hw3 = steps * HARDWARE[2].tau_p;
        assert!((hw3 - 2e-6).abs() < 3e-6, "HW3 ~2-4us, got {hw3}");
    }
}
