//! Fig. 8 — cost-readout noise (§3.5 test 1).
//!
//! NIST7x7 on 49-4-4 with additive Gaussian noise on every cost
//! measurement.  σ_C is expressed relative to the perturbation amplitude
//! Δθ (the paper normalizes "to the perturbation magnitude |θ̃|"; with
//! Δθ-normalization our measured knee lands at σ ≈ 0.3–1, matching the
//! paper's Fig. 8 axis).
//!
//! - (a) training time to 80% accuracy vs σ_C, for several fixed η:
//!   below a threshold the noise is harmless; above it training slows
//!   and then fails.
//! - (b) max achievable η (≥80% of replicas converge) and the resulting
//!   minimum training time vs σ_C: less noise → larger η → faster.
//!
//! Output: `results/fig8.csv`.

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::{
    converged_fraction, replica_stats, solve_times, MgdConfig, MgdTrainer, ScheduleKind,
    TrainOptions,
};
use crate::datasets::nist7x7;
use crate::metrics::{CsvWriter, Quartiles};
use crate::noise::NoiseConfig;
use crate::perturb::PerturbKind;

#[derive(Debug, Clone)]
pub struct Fig8Config {
    pub replicas: usize,
    pub amplitude: f32,
    pub etas: Vec<f32>,
    pub eta_grid: Vec<f32>,
    pub sigma_rel: Vec<f32>,
    pub max_steps: u64,
    pub train_n: usize,
    pub target_accuracy: f32,
}

impl Default for Fig8Config {
    fn default() -> Self {
        Fig8Config {
            replicas: 10,
            amplitude: 0.01,
            // The paper's η = 0.5/1/3 are in its own unit convention; the
            // calibrated equivalents for this implementation (EXPERIMENTS.md
            // §Calibration) are ~30x smaller.
            etas: vec![0.05, 0.1, 0.2],
            eta_grid: vec![0.025, 0.05, 0.1, 0.2, 0.4],
            sigma_rel: vec![0.0, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0],
            max_steps: 400_000,
            train_n: 8192,
            target_accuracy: 0.75,
        }
    }
}

const LAYERS: [usize; 3] = [49, 4, 4];

fn cell(
    ctx: &RunContext,
    cfg: &Fig8Config,
    sigma_rel: f32,
    eta: f32,
    replicas: usize,
) -> Result<(f64, Option<f64>)> {
    let data = nist7x7(cfg.train_n, ctx.seed);
    // σ_C expressed in units of the per-parameter perturbation amplitude
    // Δθ (normalizing by the full vector magnitude Δθ·√P places the
    // paper's σ ≈ 1 knee at ~15x the cost-modulation scale and nothing
    // trains; Δθ-normalization reproduces the knee — EXPERIMENTS.md).
    let sigma_abs = sigma_rel * cfg.amplitude;
    let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
        let mut dev = native_mlp(&LAYERS, 1, seed)?;
        let mcfg = MgdConfig {
            tau_x: 1,
            tau_theta: 1,
            tau_p: 1,
            eta,
            amplitude: cfg.amplitude,
            kind: PerturbKind::RademacherCode,
            noise: NoiseConfig { sigma_cost: sigma_abs, sigma_update: 0.0 },
            seed,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
        let opts = TrainOptions {
            max_steps: ctx.scaled(cfg.max_steps, 20_000),
            eval_every: 4000,
            target_accuracy: Some(cfg.target_accuracy),
            ..Default::default()
        };
        tr.train(&opts, None)
    })?;
    let frac = converged_fraction(&outcomes);
    let times: Vec<f64> = solve_times(&outcomes).iter().map(|&t| t as f64).collect();
    Ok((frac, Quartiles::of(&times).map(|q| q.median)))
}

impl Fig8Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig8Config::default();
        let o = ctx.overrides("fig8")?;
        Ok(Fig8Config {
            replicas: o.usize("replicas", d.replicas)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            etas: o.f32_vec("etas", &d.etas)?,
            eta_grid: o.f32_vec("eta_grid", &d.eta_grid)?,
            sigma_rel: o.f32_vec("sigma_rel", &d.sigma_rel)?,
            max_steps: o.u64("max_steps", d.max_steps)?,
            train_n: o.usize("train_n", d.train_n)?,
            target_accuracy: o.f32("target_accuracy", d.target_accuracy)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig8Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 3) as usize;

    let mut csv = CsvWriter::create(
        ctx.result_path("fig8.csv"),
        &["panel", "sigma_c_rel", "eta", "converged_fraction", "median_steps"],
    )?;

    println!("fig8(a): training time vs cost noise (NIST7x7, target {}% acc)", cfg.target_accuracy * 100.0);
    for &eta in &cfg.etas {
        for &s in &cfg.sigma_rel {
            let (frac, median) = cell(ctx, &cfg, s, eta, replicas)?;
            let med = median.map_or(String::new(), |m| format!("{m:.0}"));
            println!(
                "  eta={eta:<4} sigma={s:<5} solved {:>5.1}%  median {}",
                frac * 100.0,
                if med.is_empty() { "-" } else { &med }
            );
            csv.row(&[
                "a_fixed_eta".into(),
                s.to_string(),
                eta.to_string(),
                format!("{frac:.3}"),
                med,
            ])?;
        }
    }

    println!("fig8(b): max eta vs cost noise");
    for &s in &cfg.sigma_rel {
        let mut best: Option<(f32, f64)> = None;
        for &eta in &cfg.eta_grid {
            let (frac, median) = cell(ctx, &cfg, s, eta, replicas.min(6))?;
            if frac >= 0.8 {
                if let Some(m) = median {
                    if best.map_or(true, |(be, _)| eta > be) {
                        best = Some((eta, m));
                    }
                }
            }
        }
        let (eta_str, med_str) = match best {
            Some((e, m)) => (e.to_string(), format!("{m:.0}")),
            None => (String::new(), String::new()),
        };
        println!(
            "  sigma={s:<5} max_eta {}  min time {}",
            if eta_str.is_empty() { "-" } else { &eta_str },
            if med_str.is_empty() { "-" } else { &med_str }
        );
        csv.row(&["b_max_eta".into(), s.to_string(), eta_str, "".into(), med_str])?;
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig8.csv").display());
    Ok(())
}
