//! One harness per figure/table in the paper's evaluation (DESIGN.md §5).
//!
//! Every harness:
//! 1. has compiled-in defaults reproducing the paper's settings (scaled
//!    for the CPU testbed via [`RunContext::scale`]),
//! 2. prints the paper's rows/series to stdout, and
//! 3. writes `results/<id>.csv` for plotting.
//!
//! Run them with `mgd run <id>` (or `mgd run all`).

pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fig10;
pub mod table2;
pub mod table3;

use anyhow::{bail, Result};

use crate::config::RunContext;

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "table2", "table3",
];

/// Dispatch an experiment by id.
pub fn run(id: &str, ctx: &RunContext) -> Result<()> {
    match id {
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "fig9" => fig9::run(ctx),
        "fig10" => fig10::run(ctx),
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        "all" => {
            for id in ALL {
                eprintln!("\n================ {id} ================");
                run(id, ctx)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?}; known: {ALL:?} or 'all'"),
    }
}
