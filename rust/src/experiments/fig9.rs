//! Fig. 9 — noisy parameter updates (§3.5 test 2, Eq. 5).
//!
//! XOR on 2-2-1 with Gaussian noise added to every weight update,
//! θ ← θ − ηG + θ_noise, θ_noise ~ N(0, σθ·Δθ) (σθ expressed in units of
//! the perturbation amplitude, as in the paper's normalization).
//!
//! Reproduced phenomena:
//! - (a) at τθ = 1, large σθ prevents convergence entirely, and
//!   *increasing* η can rescue it (ηG must outgrow the noise floor);
//! - (b) at τθ = 100 the accumulated G makes ηG ~100× larger relative to
//!   the per-update noise, so even the largest σθ trains fine;
//! - (c, d) training time vs η for both τθ.
//!
//! Output: `results/fig9.csv`.

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::{
    converged_fraction, replica_stats, solve_times, MgdConfig, MgdTrainer, ScheduleKind,
    TrainOptions,
};
use crate::datasets::xor;
use crate::metrics::{CsvWriter, Quartiles};
use crate::noise::NoiseConfig;
use crate::perturb::PerturbKind;

#[derive(Debug, Clone)]
pub struct Fig9Config {
    pub replicas: usize,
    pub amplitude: f32,
    pub sigmas: Vec<f32>,
    pub etas: Vec<f32>,
    pub tau_thetas: Vec<u64>,
    pub max_steps: u64,
    pub target_accuracy: f32,
}

impl Default for Fig9Config {
    fn default() -> Self {
        Fig9Config {
            replicas: 16,
            amplitude: 0.05,
            sigmas: vec![0.0, 0.01, 0.03, 0.1, 0.3],
            etas: vec![0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0],
            tau_thetas: vec![1, 100],
            max_steps: 300_000,
            target_accuracy: 0.93,
        }
    }
}

impl Fig9Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig9Config::default();
        let o = ctx.overrides("fig9")?;
        Ok(Fig9Config {
            replicas: o.usize("replicas", d.replicas)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            sigmas: o.f32_vec("sigmas", &d.sigmas)?,
            etas: o.f32_vec("etas", &d.etas)?,
            tau_thetas: o.u64_vec("tau_thetas", &d.tau_thetas)?,
            max_steps: o.u64("max_steps", d.max_steps)?,
            target_accuracy: o.f32("target_accuracy", d.target_accuracy)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig9Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 4) as usize;
    let data = xor();

    let mut csv = CsvWriter::create(
        ctx.result_path("fig9.csv"),
        &["tau_theta", "sigma_theta", "eta", "converged_fraction", "median_steps"],
    )?;

    for &tau in &cfg.tau_thetas {
        println!("fig9: tau_theta = {tau}");
        for &sigma in &cfg.sigmas {
            for &eta in &cfg.etas {
                let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
                    let mut dev = native_mlp(&[2, 2, 1], 1, seed)?;
                    let mcfg = MgdConfig {
                        tau_x: 1,
                        tau_theta: tau,
                        tau_p: 1,
                        eta,
                        amplitude: cfg.amplitude,
                        kind: PerturbKind::RademacherCode,
                        noise: NoiseConfig {
                            sigma_cost: 0.0,
                            // σθ in units of Δθ (paper's normalization).
                            sigma_update: sigma * cfg.amplitude,
                        },
                        seed,
                        ..Default::default()
                    };
                    let mut tr =
                        MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
                    let opts = TrainOptions {
                        max_steps: ctx.scaled(cfg.max_steps, 20_000),
                        eval_every: 500.max(tau),
                        target_accuracy: Some(cfg.target_accuracy),
                        ..Default::default()
                    };
                    tr.train(&opts, None)
                })?;
                let frac = converged_fraction(&outcomes);
                let times: Vec<f64> =
                    solve_times(&outcomes).iter().map(|&t| t as f64).collect();
                let med = Quartiles::of(&times)
                    .map_or(String::new(), |q| format!("{:.0}", q.median));
                csv.row(&[
                    tau.to_string(),
                    sigma.to_string(),
                    eta.to_string(),
                    format!("{frac:.3}"),
                    med.clone(),
                ])?;
                if frac > 0.0 {
                    println!(
                        "  sigma={sigma:<5} eta={eta:<5} converged {:>5.1}%  median {}",
                        frac * 100.0,
                        if med.is_empty() { "-" } else { &med }
                    );
                }
            }
        }
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig9.csv").display());
    Ok(())
}
