//! Fig. 5 — convergence of the gradient approximation G to the true
//! gradient.
//!
//! With τθ = ∞ (no updates) and τx = τp = 1, the homodyne integrator G
//! accumulates forever; the angle between G and the true gradient
//! ∂C/∂θ (computed by backprop via the `grad` AOT artifact) decreases
//! with integration time, more slowly for networks with more parameters:
//! 2-bit parity (9 p) < 4-bit parity (25 p) < NIST7x7 (220 p).
//!
//! The MGD side runs model-free on the NativeDevice; the true gradient
//! comes from PJRT.  Since no updates fire, θ is constant and the true
//! gradient is computed once per replica.
//!
//! Output: `results/fig5.csv` — problem, step, median/q1/q3 angle.

use anyhow::Result;

use super::common::{log_checkpoints, native_from_spec};
use crate::config::RunContext;
use crate::coordinator::{MgdConfig, MgdTrainer, ScheduleKind};
use crate::datasets::{nist7x7, parity, Dataset};
use crate::device::HardwareDevice;
use crate::metrics::{angle_degrees, CsvWriter, Quartiles};
use crate::model::ModelSpec;
use crate::perturb::PerturbKind;
use crate::runtime::{Runtime, Value};

#[derive(Debug, Clone)]
pub struct Fig5Config {
    pub max_steps: u64,
    pub replicas_parity: usize,
    pub replicas_nist: usize,
    pub amplitude: f32,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config { max_steps: 100_000, replicas_parity: 40, replicas_nist: 8, amplitude: 0.01 }
    }
}

struct Problem {
    name: &'static str,
    spec: ModelSpec,
    dataset: Dataset,
    grad_artifact: &'static str,
    replicas: usize,
}

impl Fig5Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig5Config::default();
        let o = ctx.overrides("fig5")?;
        Ok(Fig5Config {
            max_steps: o.u64("max_steps", d.max_steps)?,
            replicas_parity: o.usize("replicas_parity", d.replicas_parity)?,
            replicas_nist: o.usize("replicas_nist", d.replicas_nist)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig5Config::load(ctx)?;
    let rt = Runtime::new(&ctx.artifact_dir)?;
    let max_steps = ctx.scaled(cfg.max_steps, 1000);
    let checkpoints = log_checkpoints(max_steps, 4);

    let problems = vec![
        Problem {
            name: "parity2",
            spec: ModelSpec::sigmoid_mlp(&[2, 2, 1]),
            dataset: parity(2),
            grad_artifact: "xor221_grad",
            replicas: ctx.scaled(cfg.replicas_parity as u64, 4) as usize,
        },
        Problem {
            name: "parity4",
            spec: ModelSpec::sigmoid_mlp(&[4, 4, 1]),
            dataset: parity(4),
            grad_artifact: "parity441_grad",
            replicas: ctx.scaled(cfg.replicas_parity as u64, 4) as usize,
        },
        Problem {
            name: "nist7x7",
            spec: ModelSpec::sigmoid_mlp(&[49, 4, 4]),
            // Sized to the grad artifact's eval batch so the "true
            // gradient" covers exactly the samples MGD cycles through.
            dataset: nist7x7(512, ctx.seed),
            grad_artifact: "nist744_grad",
            replicas: ctx.scaled(cfg.replicas_nist as u64, 2) as usize,
        },
    ];

    let mut csv = CsvWriter::create(
        ctx.result_path("fig5.csv"),
        &["problem", "n_params", "step", "median_angle_deg", "q1", "q3", "replicas"],
    )?;

    for prob in &problems {
        let grad_exe = rt.executable(prob.grad_artifact)?;
        let p = prob.spec.param_count();
        let b = grad_exe.meta.inputs[1].shape[0];
        anyhow::ensure!(
            b == prob.dataset.n,
            "{}: grad artifact batch {b} != dataset size {}",
            prob.name,
            prob.dataset.n
        );

        // angles[replica][checkpoint]
        let mut angles = vec![vec![f64::NAN; checkpoints.len()]; prob.replicas];
        for (r, row) in angles.iter_mut().enumerate() {
            let seed = ctx.seed + r as u64;
            let mut dev = native_from_spec(prob.spec.clone(), 1, seed)?;
            let theta = dev.get_params()?;
            // True gradient over the full dataset (constant: τθ = ∞).
            let mut shape = vec![b];
            shape.extend_from_slice(&prob.dataset.input_shape);
            let out = grad_exe.run(&[
                Value::f32(theta.clone(), &[p]),
                Value::f32(prob.dataset.x.clone(), &shape),
                Value::f32(prob.dataset.y.clone(), &[b, prob.dataset.n_outputs]),
            ])?;
            let true_grad = out[1].as_f32()?.to_vec();

            let mcfg = MgdConfig {
                tau_x: 1,
                tau_theta: u64::MAX,
                tau_p: 1,
                amplitude: cfg.amplitude,
                kind: PerturbKind::RademacherCode,
                seed,
                ..Default::default()
            };
            let mut tr = MgdTrainer::new(&mut dev, &prob.dataset, mcfg, ScheduleKind::Cyclic);
            let mut next_cp = 0usize;
            for step in 1..=max_steps {
                tr.step()?;
                if next_cp < checkpoints.len() && step == checkpoints[next_cp] {
                    row[next_cp] = angle_degrees(tr.gradient(), &true_grad);
                    next_cp += 1;
                }
            }
        }

        for (ci, &cp) in checkpoints.iter().enumerate() {
            let vals: Vec<f64> = angles
                .iter()
                .map(|row| row[ci])
                .filter(|v| v.is_finite())
                .collect();
            if let Some(q) = Quartiles::of(&vals) {
                csv.row(&[
                    prob.name.to_string(),
                    p.to_string(),
                    cp.to_string(),
                    format!("{:.3}", q.median),
                    format!("{:.3}", q.q1),
                    format!("{:.3}", q.q3),
                    q.n.to_string(),
                ])?;
            }
        }
        let final_vals: Vec<f64> = angles.iter().map(|r| *r.last().unwrap()).collect();
        let q = Quartiles::of(&final_vals).unwrap();
        println!(
            "fig5: {:<8} P={:<4} angle @ {} steps: median {:.1} deg (q1 {:.1}, q3 {:.1})",
            prob.name, p, max_steps, q.median, q.q1, q.q3
        );
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig5.csv").display());
    Ok(())
}
