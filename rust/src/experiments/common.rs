//! Shared helpers for the experiment harnesses.

use anyhow::Result;

use crate::device::NativeDevice;
use crate::model::ModelSpec;
use crate::noise::NeuronDefects;
use crate::optim::init_params_uniform;
use crate::rng::Rng;

/// Build a NativeDevice MLP with uniform(−1, 1) initialization — the
/// paper's "random initialization" for its sigmoid networks.
pub fn native_mlp(layers: &[usize], batch: usize, seed: u64) -> Result<NativeDevice> {
    native_mlp_with_defects(layers, batch, seed, None)
}

/// Same, with optional per-neuron activation defects (Fig. 10).
pub fn native_mlp_with_defects(
    layers: &[usize],
    batch: usize,
    seed: u64,
    defects: Option<NeuronDefects>,
) -> Result<NativeDevice> {
    let mut spec = ModelSpec::sigmoid_mlp(layers);
    if let Some(d) = defects {
        spec = spec.with_defects(d)?;
    }
    native_from_spec(spec, batch, seed)
}

/// Build a device for an arbitrary [`ModelSpec`] with the paper's
/// uniform(−1, 1) initialization (defects ride on the spec).
pub fn native_from_spec(spec: ModelSpec, batch: usize, seed: u64) -> Result<NativeDevice> {
    use crate::device::HardwareDevice;
    let mut dev = NativeDevice::from_spec(spec, batch)?;
    let mut rng = Rng::new(seed ^ 0x494e_4954); // "INIT"
    let mut theta = vec![0f32; dev.n_params()];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta)?;
    Ok(dev)
}

/// Log-spaced u64 checkpoints from 1 to `max` inclusive (deduplicated).
pub fn log_checkpoints(max: u64, per_decade: usize) -> Vec<u64> {
    let mut out = Vec::new();
    let mut last = 0u64;
    let decades = (max as f64).log10();
    let n = (decades * per_decade as f64).ceil() as usize + 1;
    for i in 0..=n {
        let v = 10f64.powf(i as f64 / per_decade as f64).round() as u64;
        let v = v.min(max).max(1);
        if v != last {
            out.push(v);
            last = v;
        }
    }
    if *out.last().unwrap() != max {
        out.push(max);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::HardwareDevice;

    #[test]
    fn native_mlp_is_initialized() {
        let mut dev = native_mlp(&[2, 2, 1], 1, 0).unwrap();
        let theta = dev.get_params().unwrap();
        assert_eq!(theta.len(), 9);
        assert!(theta.iter().any(|&v| v != 0.0));
        // Determinism per seed.
        let mut dev2 = native_mlp(&[2, 2, 1], 1, 0).unwrap();
        assert_eq!(theta, dev2.get_params().unwrap());
    }

    #[test]
    fn checkpoints_are_monotone_and_bounded() {
        let cps = log_checkpoints(100_000, 3);
        assert_eq!(*cps.first().unwrap(), 1);
        assert_eq!(*cps.last().unwrap(), 100_000);
        for w in cps.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
