//! Fig. 10 — per-neuron activation-function defects (§3.5 test 3).
//!
//! NIST7x7 on a 49-4-4 NativeDevice whose neurons have static random
//! generalized-logistic activations, f_k(a) = α_k(1+e^{−β_k(a−a_k)})^{−1}
//! + b_k with α, β ~ N(1, σ_a) and a, b ~ N(0, σ_a).  MGD never sees the
//! defect table — the device is a black box — yet trains through
//! moderate defects with only ~2× slowdown; very large σ_a prevents the
//! output neurons from expressing the targets at all and convergence
//! collapses (the paper's observed cliff at σ_a > 0.25).
//!
//! Output: `results/fig10.csv` — σ_a, converged fraction, median time.

use anyhow::Result;

use super::common::native_mlp_with_defects;
use crate::config::RunContext;
use crate::coordinator::{
    converged_fraction, replica_stats, solve_times, MgdConfig, MgdTrainer, ScheduleKind,
    TrainOptions,
};
use crate::datasets::nist7x7;
use crate::metrics::{CsvWriter, Quartiles};
use crate::noise::NeuronDefects;
use crate::perturb::PerturbKind;
use crate::rng::Rng;

#[derive(Debug, Clone)]
pub struct Fig10Config {
    pub replicas: usize,
    pub amplitude: f32,
    pub eta: f32,
    pub sigmas: Vec<f32>,
    pub max_steps: u64,
    pub train_n: usize,
    pub target_accuracy: f32,
}

impl Default for Fig10Config {
    fn default() -> Self {
        Fig10Config {
            replicas: 12,
            amplitude: 0.01,
            eta: 0.1,
            sigmas: vec![0.0, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4],
            max_steps: 500_000,
            train_n: 8192,
            target_accuracy: 0.75,
        }
    }
}

const LAYERS: [usize; 3] = [49, 4, 4];

impl Fig10Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig10Config::default();
        let o = ctx.overrides("fig10")?;
        Ok(Fig10Config {
            replicas: o.usize("replicas", d.replicas)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            eta: o.f32("eta", d.eta)?,
            sigmas: o.f32_vec("sigmas", &d.sigmas)?,
            max_steps: o.u64("max_steps", d.max_steps)?,
            train_n: o.usize("train_n", d.train_n)?,
            target_accuracy: o.f32("target_accuracy", d.target_accuracy)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig10Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 3) as usize;
    let data = nist7x7(cfg.train_n, ctx.seed);

    let mut csv = CsvWriter::create(
        ctx.result_path("fig10.csv"),
        &["sigma_a", "converged_fraction", "median_steps", "q1", "q3", "replicas"],
    )?;

    println!(
        "fig10: activation defects on NIST7x7 (eta={}, target {}%)",
        cfg.eta,
        cfg.target_accuracy * 100.0
    );
    let n_neurons: usize = LAYERS[1..].iter().sum();
    for &sigma_a in &cfg.sigmas {
        let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
            // Independent defect table AND independent init per replica
            // ("25 different random network initializations and
            // activation-function randomizations").
            let defects = if sigma_a == 0.0 {
                NeuronDefects::identity(n_neurons)
            } else {
                NeuronDefects::sample(n_neurons, sigma_a, &mut Rng::new(seed ^ 0x00de_fec7))
            };
            let mut dev = native_mlp_with_defects(&LAYERS, 1, seed, Some(defects))?;
            let mcfg = MgdConfig {
                tau_x: 1,
                tau_theta: 1,
                tau_p: 1,
                eta: cfg.eta,
                amplitude: cfg.amplitude,
                kind: PerturbKind::RademacherCode,
                seed,
                ..Default::default()
            };
            let mut tr = MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
            let opts = TrainOptions {
                max_steps: ctx.scaled(cfg.max_steps, 20_000),
                eval_every: 4000,
                target_accuracy: Some(cfg.target_accuracy),
                ..Default::default()
            };
            tr.train(&opts, None)
        })?;
        let frac = converged_fraction(&outcomes);
        let times: Vec<f64> = solve_times(&outcomes).iter().map(|&t| t as f64).collect();
        let q = Quartiles::of(&times);
        let (med, q1, q3) = match q {
            Some(q) => (
                format!("{:.0}", q.median),
                format!("{:.0}", q.q1),
                format!("{:.0}", q.q3),
            ),
            None => (String::new(), String::new(), String::new()),
        };
        println!(
            "  sigma_a={sigma_a:<5} converged {:>5.1}%  median {}",
            frac * 100.0,
            if med.is_empty() { "-" } else { &med }
        );
        csv.row(&[
            sigma_a.to_string(),
            format!("{frac:.3}"),
            med,
            q1,
            q3,
            replicas.to_string(),
        ])?;
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig10.csv").display());
    Ok(())
}
