//! Table 2 — MGD vs backpropagation on the paper's four datasets.
//!
//! Runs the fused on-chip MGD trainer (PJRT `mgd_scan` artifact, random
//! code perturbations) for each row and reports test accuracy at
//! geometric step checkpoints, plus the backprop-SGD accuracy on the same
//! network as the comparator column.
//!
//! Substitutions & scaling (DESIGN.md §3, EXPERIMENTS.md):
//! - Fashion-MNIST / CIFAR-10 → seeded synthetic 10-class image sets
//!   (identical tensor shapes);
//! - step budgets default to ~10³–10⁵ on this CPU testbed instead of the
//!   paper's 10⁷ (scaled via `--scale`); the *shape* under test is MGD
//!   climbing toward (but trailing) backprop, τθ having marginal effect,
//!   and large batch training stably.
//!
//! Output: `results/table2.csv`.

use anyhow::Result;

use crate::config::RunContext;
use crate::coordinator::{MgdConfig, OnChipTrainer, TrainOptions};
use crate::datasets::{nist7x7, parity, synthetic_cifar, synthetic_fmnist, Dataset};
use crate::metrics::CsvWriter;
use crate::optim::{init_params, BackpropTrainer};
use crate::perturb::PerturbKind;
use crate::rng::Rng;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct Table2Config {
    /// Per-row MGD step budgets (before `--scale`).
    pub steps_xor: u64,
    pub steps_nist: u64,
    pub steps_fmnist: u64,
    pub steps_cifar: u64,
    /// Backprop step budgets.
    pub bp_steps_small: u64,
    pub bp_steps_cnn: u64,
    /// τθ sweep for the Fashion rows.
    pub fmnist_tau_thetas: Vec<u64>,
    pub amplitude: f32,
    pub eta_cnn: f32,
}

impl Default for Table2Config {
    fn default() -> Self {
        Table2Config {
            steps_xor: 20_000,
            steps_nist: 1_000_000,
            // CNN budgets sit inside the η=0.05 stability window validated
            // by the E2E example (divergence observed past ~2.5k steps).
            steps_fmnist: 2_000,
            steps_cifar: 1_000,
            bp_steps_small: 20_000,
            bp_steps_cnn: 1_500,
            fmnist_tau_thetas: vec![1, 10, 100, 1000],
            amplitude: 0.01,
            // 0.05 sits on the stability edge (diverges for some inits
            // past ~1k steps); 0.02 climbs monotonically for all tested.
            eta_cnn: 0.02,
        }
    }
}

struct Row {
    task: &'static str,
    model: &'static str,
    dataset: Dataset,
    eval: Dataset,
    tau_theta: u64,
    eta: f32,
    steps: u64,
    bp_steps: u64,
    bp_eta: f32,
}

impl Table2Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Table2Config::default();
        let o = ctx.overrides("table2")?;
        Ok(Table2Config {
            steps_xor: o.u64("steps_xor", d.steps_xor)?,
            steps_nist: o.u64("steps_nist", d.steps_nist)?,
            steps_fmnist: o.u64("steps_fmnist", d.steps_fmnist)?,
            steps_cifar: o.u64("steps_cifar", d.steps_cifar)?,
            bp_steps_small: o.u64("bp_steps_small", d.bp_steps_small)?,
            bp_steps_cnn: o.u64("bp_steps_cnn", d.bp_steps_cnn)?,
            fmnist_tau_thetas: o.u64_vec("fmnist_tau_thetas", &d.fmnist_tau_thetas)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            eta_cnn: o.f32("eta_cnn", d.eta_cnn)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Table2Config::load(ctx)?;
    let rt = Runtime::new(&ctx.artifact_dir)?;

    let mut rows: Vec<Row> = Vec::new();
    // XOR row (paper: τθ=1, η=5, batch 1).
    rows.push(Row {
        task: "2-bit parity",
        model: "xor221",
        dataset: parity(2),
        eval: parity(2),
        tau_theta: 1,
        eta: 0.5,
        steps: ctx.scaled(cfg.steps_xor, 2_000),
        bp_steps: ctx.scaled(cfg.bp_steps_small, 2_000),
        bp_eta: 0.5,
    });
    // NIST7x7 rows (paper: η = 3 and 0.5 in its unit convention; the
    // calibrated equivalents here are 0.2 and 0.1 — EXPERIMENTS.md
    // §Calibration — preserving the "larger η faster early, smaller η
    // better late" contrast).
    for eta in [0.2f32, 0.1] {
        let train = nist7x7(44_136, ctx.seed);
        let eval = nist7x7(2048, ctx.seed + 999);
        rows.push(Row {
            task: "N-I-S-T",
            model: "nist744",
            dataset: train,
            eval,
            tau_theta: 1,
            eta,
            steps: ctx.scaled(cfg.steps_nist, 10_000),
            bp_steps: ctx.scaled(cfg.bp_steps_small, 2_000),
            bp_eta: 0.5,
        });
    }
    // Fashion rows: τθ sweep (paper: τθ ∈ {1,10,100,1000}, η=9, batch 1000
    // — here scan batch 100, synthetic data, scaled steps).
    for &tau in &cfg.fmnist_tau_thetas {
        let train = synthetic_fmnist(8192, ctx.seed);
        let (train, eval) = train.split_test(1024);
        rows.push(Row {
            task: "Fashion-MNIST(synthetic)",
            model: "fmnist_cnn",
            dataset: train,
            eval,
            tau_theta: tau,
            eta: cfg.eta_cnn,
            steps: ctx.scaled(cfg.steps_fmnist, 200),
            bp_steps: ctx.scaled(cfg.bp_steps_cnn, 200),
            bp_eta: 0.1,
        });
    }
    // CIFAR row.
    {
        let train = synthetic_cifar(4096, ctx.seed);
        let (train, eval) = train.split_test(512);
        rows.push(Row {
            task: "CIFAR-10(synthetic)",
            model: "cifar_cnn",
            dataset: train,
            eval,
            tau_theta: 1,
            eta: cfg.eta_cnn,
            steps: ctx.scaled(cfg.steps_cifar, 150),
            bp_steps: ctx.scaled(cfg.bp_steps_cnn, 150),
            bp_eta: 0.1,
        });
    }

    let mut csv = CsvWriter::create(
        ctx.result_path("table2.csv"),
        &[
            "task",
            "model",
            "params",
            "tau_theta",
            "eta",
            "checkpoint_steps",
            "mgd_accuracy",
            "backprop_accuracy",
        ],
    )?;

    println!(
        "{:<26} {:<11} {:>6} {:>5} {:>5}  accuracy@checkpoints (MGD) | backprop",
        "task", "model", "P", "tau", "eta"
    );
    // Backprop column is per (task, model); cache it.
    let mut bp_cache: std::collections::HashMap<String, f32> = Default::default();

    for row in &rows {
        let meta = rt.manifest.model(row.model)?.clone();
        let mut rng = Rng::new(ctx.seed ^ 0x7ab2_e2e2);
        let mut theta = vec![0f32; meta.param_count];
        init_params(&mut rng, &meta.tensors, &mut theta);

        // --- backprop comparator (cached per model) -----------------------
        let bp_key = row.model.to_string();
        if !bp_cache.contains_key(&bp_key) {
            let mut bp =
                BackpropTrainer::new(&rt, row.model, &row.dataset, theta.clone(), row.bp_eta, ctx.seed)?;
            let opts = TrainOptions {
                max_steps: row.bp_steps,
                eval_every: (row.bp_steps / 10).max(1),
                ..Default::default()
            };
            let res = bp.train(&opts, Some(&row.eval))?;
            let best = res
                .eval_trace
                .iter()
                .map(|&(_, _, a)| a)
                .fold(0f32, f32::max);
            bp_cache.insert(bp_key.clone(), best);
        }
        let bp_acc = bp_cache[&bp_key];

        // --- MGD on-chip run ----------------------------------------------
        let mcfg = MgdConfig {
            tau_x: 1,
            tau_theta: row.tau_theta,
            tau_p: 1,
            eta: row.eta,
            amplitude: cfg.amplitude,
            kind: PerturbKind::RademacherCode,
            seed: ctx.seed,
            ..Default::default()
        };
        let mut tr = OnChipTrainer::new(&rt, row.model, &row.dataset, theta, mcfg)?;
        // Geometric checkpoints: 4 per run.
        let cps: Vec<u64> = (1..=4u32)
            .map(|i| {
                (row.steps as f64).powf(i as f64 / 4.0).round() as u64
            })
            .map(|v| v.max(tr.window_steps() as u64))
            .collect();
        let mut acc_at = Vec::new();
        for &cp in &cps {
            while tr.steps() < cp {
                tr.window()?;
            }
            let (_, correct) = tr.evaluate(&row.eval)?;
            acc_at.push((tr.steps(), correct / row.eval.n as f32));
        }

        let accs: Vec<String> = acc_at
            .iter()
            .map(|(s, a)| format!("{:.1}%@{}", a * 100.0, s))
            .collect();
        println!(
            "{:<26} {:<11} {:>6} {:>5} {:>5}  {} | {:.1}%",
            row.task,
            row.model,
            meta.param_count,
            row.tau_theta,
            row.eta,
            accs.join(" "),
            bp_acc * 100.0
        );
        for (s, a) in &acc_at {
            csv.row(&[
                row.task.into(),
                row.model.into(),
                meta.param_count.to_string(),
                row.tau_theta.to_string(),
                row.eta.to_string(),
                s.to_string(),
                format!("{a:.4}"),
                format!("{bp_acc:.4}"),
            ])?;
        }
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("table2.csv").display());
    Ok(())
}
