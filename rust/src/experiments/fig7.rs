//! Fig. 7 — equivalence of the perturbation families.
//!
//! XOR on 2-2-1 with the paper's hyper-parameters (τx = 250, τθ = 1,
//! η = 0.05, τp = 1 discrete / Δf ≈ 0.3 analog): training-time box plots
//! for sequential finite-difference, Walsh codes, random (Rademacher)
//! codes, discrete sinusoids, and the fully-analog loop.  All families
//! share one broadcast-cost channel, so their information rate — and
//! hence training time — is approximately equal (the paper's §5
//! "multiple access" argument).
//!
//! Output: `results/fig7.csv` — per-replica solve times per family.

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::analog::{AnalogConfig, AnalogTrainer};
use crate::coordinator::{
    converged_fraction, replica_stats, solve_times, MgdConfig, MgdTrainer, ScheduleKind,
    TrainOptions,
};
use crate::datasets::xor;
use crate::metrics::{CsvWriter, Quartiles};
use crate::perturb::PerturbKind;

#[derive(Debug, Clone)]
pub struct Fig7Config {
    pub replicas: usize,
    pub eta: f32,
    pub amplitude: f32,
    pub tau_x: u64,
    pub max_steps: u64,
}

impl Default for Fig7Config {
    fn default() -> Self {
        Fig7Config { replicas: 40, eta: 0.05, amplitude: 0.05, tau_x: 250, max_steps: 2_000_000 }
    }
}

impl Fig7Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig7Config::default();
        let o = ctx.overrides("fig7")?;
        Ok(Fig7Config {
            replicas: o.usize("replicas", d.replicas)?,
            eta: o.f32("eta", d.eta)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            tau_x: o.u64("tau_x", d.tau_x)?,
            max_steps: o.u64("max_steps", d.max_steps)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig7Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 5) as usize;
    let max_steps = ctx.scaled(cfg.max_steps, 50_000);
    let data = xor();

    let mut csv = CsvWriter::create(
        ctx.result_path("fig7.csv"),
        &["family", "seed", "solved", "solve_steps"],
    )?;

    let opts = TrainOptions {
        max_steps,
        eval_every: 1000,
        target_cost: Some(0.04),
        ..Default::default()
    };

    let discrete: [(&str, PerturbKind); 4] = [
        ("sequential_fd", PerturbKind::SequentialFd),
        ("walsh_code", PerturbKind::WalshCode),
        ("rademacher_code", PerturbKind::RademacherCode),
        ("sinusoidal", PerturbKind::Sinusoidal),
    ];
    println!(
        "fig7: XOR, tau_x={}, tau_theta=1, eta={}, {replicas} replicas, budget {max_steps} steps",
        cfg.tau_x, cfg.eta
    );
    for (family, kind) in discrete {
        let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
            let mut dev = native_mlp(&[2, 2, 1], 1, seed)?;
            let mcfg = MgdConfig {
                tau_x: cfg.tau_x,
                tau_theta: 1,
                tau_p: 1,
                eta: cfg.eta,
                amplitude: cfg.amplitude,
                kind,
                seed,
                ..Default::default()
            };
            let mut tr = MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
            tr.train(&opts, None)
        })?;
        emit(&mut csv, family, &outcomes)?;
    }

    // Fully-analog loop (sinusoids + highpass + lowpass bank, Fig. 2d).
    {
        let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
            let mut dev = native_mlp(&[2, 2, 1], 1, seed)?;
            let acfg = AnalogConfig {
                tau_x: cfg.tau_x,
                tau_theta: 1.0,
                tau_hp: 10.0,
                tau_p: 3, // Δf ≈ 0.33, the paper's analog bandwidth
                // The analog loop's stable region sits at ~2x the discrete
                // amplitude/learning rate (calibration in EXPERIMENTS.md).
                eta: 2.0 * cfg.eta,
                amplitude: 2.0 * cfg.amplitude,
                seed,
                ..Default::default()
            };
            let mut tr = AnalogTrainer::new(&mut dev, &data, acfg, ScheduleKind::Cyclic);
            tr.train(&opts, None)
        })?;
        emit(&mut csv, "analog", &outcomes)?;
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig7.csv").display());
    Ok(())
}

fn emit(
    csv: &mut CsvWriter,
    family: &str,
    outcomes: &[crate::coordinator::ReplicaOutcome],
) -> Result<()> {
    for o in outcomes {
        csv.row(&[
            family.to_string(),
            o.seed.to_string(),
            (o.result.solved() as u8).to_string(),
            o.result.solved_at.map_or(String::new(), |s| s.to_string()),
        ])?;
    }
    let times: Vec<f64> = solve_times(outcomes).iter().map(|&t| t as f64).collect();
    let frac = converged_fraction(outcomes);
    match Quartiles::of(&times) {
        Some(q) => println!(
            "  {family:<16} solved {:>5.1}%  median {:>9.0}  [q1 {:>9.0}, q3 {:>9.0}]",
            frac * 100.0,
            q.median,
            q.q1,
            q.q3
        ),
        None => println!("  {family:<16} solved 0%"),
    }
    Ok(())
}
