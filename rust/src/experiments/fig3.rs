//! Fig. 3 — mini-batching through the τθ/τx ratio.
//!
//! A 3-parameter network and a 4-sample dataset, with τθ = 4·τx: all four
//! samples are shown (one per timestep) inside a single gradient
//! integration period, so each parameter update integrates the whole
//! dataset — batch size τθ/τx = 4 on one-sample-at-a-time hardware.
//! The trace shows G accumulating every step and resetting at each τθ
//! boundary, with θ stepping opposite to G (Eq. 4).
//!
//! Output: `results/fig3.csv` (step, sample shown, G, θ, cost).

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::{MgdConfig, MgdTrainer, ScheduleKind};
use crate::datasets::xor;
use crate::metrics::CsvWriter;
use crate::perturb::PerturbKind;

pub fn run(ctx: &RunContext) -> Result<()> {
    let steps = ctx.scaled(160, 32);
    let data = xor(); // 4 samples, 2 inputs — matches the figure's setup
    let mut dev = native_mlp(&[2, 1], 1, ctx.seed)?;
    let cfg = MgdConfig {
        tau_x: 1,
        tau_theta: 4, // batch size τθ/τx = 4
        tau_p: 1,
        eta: 0.5,
        amplitude: 0.1,
        kind: PerturbKind::RademacherCode,
        seed: ctx.seed,
        ..Default::default()
    };
    let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);

    let mut csv = CsvWriter::create(
        ctx.result_path("fig3.csv"),
        &["step", "sample", "g0", "g1", "g2", "theta0", "theta1", "theta2", "cost", "updated"],
    )?;
    for i in 0..steps {
        let sample = (i % 4) as usize; // cyclic schedule, τx = 1
        let out = tr.step()?;
        // G was reset if an update fired; record post-step state (the
        // figure's sawtooth).
        let g = tr.gradient().to_vec();
        let theta = tr.device_params()?;
        csv.row(&[
            out.step.to_string(),
            sample.to_string(),
            format!("{:.6}", g[0]),
            format!("{:.6}", g[1]),
            format!("{:.6}", g[2]),
            format!("{:.6}", theta[0]),
            format!("{:.6}", theta[1]),
            format!("{:.6}", theta[2]),
            format!("{:.6}", out.cost),
            (out.updated as u8).to_string(),
        ])?;
    }
    csv.flush()?;

    println!("fig3: batching trace, tau_theta/tau_x = 4 over a 4-sample dataset");
    println!("      G accumulates 4 samples then resets at each update (sawtooth)");
    println!("      -> {}", ctx.result_path("fig3.csv").display());
    Ok(())
}
