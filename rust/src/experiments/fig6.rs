//! Fig. 6 — effect of the gradient-integration time τθ on training time.
//!
//! XOR on 2-2-1 over a τθ sweep with the batch ratio τθ/τx held at 1 or 4:
//!
//! - (a) fixed (low) η: with batch ratio 1, increasing τθ increases
//!   training time; with batch ratio 4, τθ has little effect — the
//!   accumulated (un-normalized) G compensates.
//! - (b) max achievable η: longer τθ forces smaller η (instability),
//!   so the *minimum* achievable training time grows with τθ.
//!
//! "Solved" = full-dataset cost < 0.04 (the paper's criterion).
//!
//! Output: `results/fig6.csv`.

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::{
    converged_fraction, replica_stats, solve_times, MgdConfig, MgdTrainer, ScheduleKind,
    TrainOptions,
};
use crate::datasets::xor;
use crate::metrics::{CsvWriter, Quartiles};
use crate::perturb::PerturbKind;

#[derive(Debug, Clone)]
pub struct Fig6Config {
    pub replicas: usize,
    pub fixed_eta: f32,
    pub amplitude: f32,
    pub max_steps: u64,
    pub tau_thetas: Vec<u64>,
    pub eta_grid: Vec<f32>,
}

impl Default for Fig6Config {
    fn default() -> Self {
        Fig6Config {
            replicas: 24,
            fixed_eta: 0.3,
            amplitude: 0.02,
            max_steps: 400_000,
            tau_thetas: vec![1, 4, 16, 64, 256, 1024],
            eta_grid: vec![0.1, 0.2, 0.5, 1.0, 2.0, 5.0, 10.0, 20.0],
        }
    }
}

/// Median steps-to-solve for one (τθ, batch-ratio, η) cell.
fn cell(
    ctx: &RunContext,
    cfg: &Fig6Config,
    tau_theta: u64,
    batch_ratio: u64,
    eta: f32,
    replicas: usize,
) -> Result<(f64, Option<f64>)> {
    let data = xor();
    // batch ratio τθ/τx: τx = τθ / ratio (≥1).
    let tau_x = (tau_theta / batch_ratio).max(1);
    let outcomes = replica_stats(replicas, ctx.seed, true, |seed| {
        let mut dev = native_mlp(&[2, 2, 1], 1, seed)?;
        let mcfg = MgdConfig {
            tau_x,
            tau_theta,
            tau_p: 1,
            eta,
            amplitude: cfg.amplitude,
            kind: PerturbKind::RademacherCode,
            seed,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, mcfg, ScheduleKind::Cyclic);
        let opts = TrainOptions {
            max_steps: ctx.scaled(cfg.max_steps, 10_000),
            eval_every: 200.max(tau_theta),
            target_cost: Some(0.04),
            ..Default::default()
        };
        tr.train(&opts, None)
    })?;
    let frac = converged_fraction(&outcomes);
    let times: Vec<f64> = solve_times(&outcomes).iter().map(|&t| t as f64).collect();
    let median = Quartiles::of(&times).map(|q| q.median);
    Ok((frac, median))
}

impl Fig6Config {
    fn load(ctx: &RunContext) -> Result<Self> {
        let d = Fig6Config::default();
        let o = ctx.overrides("fig6")?;
        Ok(Fig6Config {
            replicas: o.usize("replicas", d.replicas)?,
            fixed_eta: o.f32("fixed_eta", d.fixed_eta)?,
            amplitude: o.f32("amplitude", d.amplitude)?,
            max_steps: o.u64("max_steps", d.max_steps)?,
            tau_thetas: o.u64_vec("tau_thetas", &d.tau_thetas)?,
            eta_grid: o.f32_vec("eta_grid", &d.eta_grid)?,
        })
    }
}

pub fn run(ctx: &RunContext) -> Result<()> {
    let cfg = Fig6Config::load(ctx)?;
    let replicas = ctx.scaled(cfg.replicas as u64, 4) as usize;

    let mut csv = CsvWriter::create(
        ctx.result_path("fig6.csv"),
        &[
            "panel",
            "tau_theta",
            "batch_ratio",
            "eta",
            "converged_fraction",
            "median_steps",
        ],
    )?;

    // Panel (a): fixed low η.
    println!("fig6(a): fixed eta = {}", cfg.fixed_eta);
    for &ratio in &[1u64, 4] {
        for &tau in &cfg.tau_thetas {
            if tau < ratio {
                continue;
            }
            let (frac, median) = cell(ctx, &cfg, tau, ratio, cfg.fixed_eta, replicas)?;
            let med_str = median.map_or("".into(), |m| format!("{m:.0}"));
            println!(
                "  tau_theta={tau:<5} batch={ratio}  solved {:>5.1}%  median {} steps",
                frac * 100.0,
                if med_str.is_empty() { "-" } else { &med_str }
            );
            csv.row(&[
                "a_fixed_eta".into(),
                tau.to_string(),
                ratio.to_string(),
                cfg.fixed_eta.to_string(),
                format!("{frac:.3}"),
                med_str,
            ])?;
        }
    }

    // Panel (b): max achievable η per τθ (>=50% convergence), and the
    // training time at that η.
    println!("fig6(b): max eta sweep");
    for &ratio in &[1u64, 4] {
        for &tau in &cfg.tau_thetas {
            if tau < ratio {
                continue;
            }
            let mut best: Option<(f32, f64)> = None; // (eta, median steps)
            for &eta in &cfg.eta_grid {
                let (frac, median) = cell(ctx, &cfg, tau, ratio, eta, replicas.min(12))?;
                if frac >= 0.5 {
                    if let Some(m) = median {
                        let better = match best {
                            Some((be, _)) => eta > be,
                            None => true,
                        };
                        if better {
                            best = Some((eta, m));
                        }
                    }
                }
            }
            let (eta_str, med_str) = match best {
                Some((e, m)) => (format!("{e}"), format!("{m:.0}")),
                None => ("".into(), "".into()),
            };
            println!(
                "  tau_theta={tau:<5} batch={ratio}  max_eta {}  min time {} steps",
                if eta_str.is_empty() { "-" } else { &eta_str },
                if med_str.is_empty() { "-" } else { &med_str }
            );
            csv.row(&[
                "b_max_eta".into(),
                tau.to_string(),
                ratio.to_string(),
                eta_str,
                "".into(),
                med_str,
            ])?;
        }
    }
    csv.flush()?;
    println!("      -> {}", ctx.result_path("fig6.csv").display());
    Ok(())
}
