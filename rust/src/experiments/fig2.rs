//! Fig. 2 — one framework, four optimization algorithms.
//!
//! Reproduces the θ / θ̃ / C / C̃ traces showing that the MGD time
//! constants select classical algorithms on a 3-parameter network:
//!
//! - (a) finite-difference: sequential perturbations, τθ = P·τp
//! - (b) coordinate descent: sequential perturbations, τθ = τp
//! - (c) SPSA: simultaneous random ±Δθ, τθ = τp
//! - (d) analog: sinusoidal perturbations, continuous lowpass update
//!
//! Output: `results/fig2.csv` with per-step traces for each panel.

use anyhow::Result;

use super::common::native_mlp;
use crate::config::RunContext;
use crate::coordinator::analog::{AnalogConfig, AnalogTrainer};
use crate::coordinator::{MgdConfig, MgdTrainer, ScheduleKind};
use crate::datasets::xor;
use crate::metrics::CsvWriter;
use crate::perturb::{Perturbation, PerturbKind};

/// 3-parameter network: a single 2→1 sigmoid layer (2 weights + 1 bias).
const LAYERS: [usize; 2] = [2, 1];
const N_PARAMS: usize = 3;

pub fn run(ctx: &RunContext) -> Result<()> {
    let steps = ctx.scaled(240, 60);
    let mut csv = CsvWriter::create(
        ctx.result_path("fig2.csv"),
        &[
            "panel", "step", "theta0", "theta1", "theta2", "tt0", "tt1", "tt2", "cost",
            "c_tilde",
        ],
    )?;

    let panels: [(&str, PerturbKind, u64); 3] = [
        ("a_finite_difference", PerturbKind::SequentialFd, N_PARAMS as u64),
        ("b_coordinate_descent", PerturbKind::SequentialFd, 1),
        ("c_spsa", PerturbKind::RademacherCode, 1),
    ];

    let data = xor();
    for (panel, kind, tau_theta) in panels {
        let mut dev = native_mlp(&LAYERS, 1, ctx.seed)?;
        let cfg = MgdConfig {
            tau_x: steps + 1, // hold one sample for the whole trace
            tau_theta,
            tau_p: 1,
            eta: 0.2,
            amplitude: 0.1,
            kind,
            seed: ctx.seed,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let mut tt_probe = crate::perturb::make(kind, N_PARAMS, 0.1, 1, ctx.seed);
        let mut tt = vec![0f32; N_PARAMS];
        for _ in 0..steps {
            // Probe the perturbation the trainer will apply this step (the
            // generator is deterministic in t for these families).
            let out = tr.step()?;
            tt_probe.fill(out.step, &mut tt);
            let theta = tr_device_params(&mut tr)?;
            csv.row(&[
                panel.to_string(),
                out.step.to_string(),
                fmt(theta[0]),
                fmt(theta[1]),
                fmt(theta[2]),
                fmt(tt[0]),
                fmt(tt[1]),
                fmt(tt[2]),
                fmt(out.cost),
                fmt(out.c_tilde),
            ])?;
        }
    }

    // Panel (d): analog, sinusoidal, continuous update.
    {
        let data = xor();
        let mut dev = native_mlp(&LAYERS, 1, ctx.seed)?;
        let cfg = AnalogConfig {
            tau_x: steps + 1,
            tau_theta: 8.0,
            tau_hp: 40.0,
            tau_p: 2,
            eta: 0.05,
            amplitude: 0.1,
            seed: ctx.seed,
            ..Default::default()
        };
        let mut tr = AnalogTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let mut pert = crate::perturb::Sinusoidal::new(N_PARAMS, 0.1, 2);
        let mut tt = vec![0f32; N_PARAMS];
        for _ in 0..steps {
            let out = tr.step()?;
            crate::perturb::Perturbation::fill(&mut pert, out.step, &mut tt);
            let theta = analog_device_params(&mut tr)?;
            csv.row(&[
                "d_analog".to_string(),
                out.step.to_string(),
                fmt(theta[0]),
                fmt(theta[1]),
                fmt(theta[2]),
                fmt(tt[0]),
                fmt(tt[1]),
                fmt(tt[2]),
                fmt(out.cost),
                fmt(out.c_tilde),
            ])?;
        }
    }
    csv.flush()?;

    println!("fig2: wrote per-step traces for 4 algorithm panels ({steps} steps each)");
    println!("      panels: finite-difference (tau_theta = P*tau_p), coordinate descent");
    println!("      (tau_theta = tau_p), SPSA (random codes), analog (sinusoidal+lowpass)");
    println!("      -> {}", ctx.result_path("fig2.csv").display());
    Ok(())
}

fn fmt(v: f32) -> String {
    format!("{v:.6}")
}

// Trace helpers: the trainers own &mut device, so parameter snapshots go
// through small accessors kept here to avoid widening the trainer API.
fn tr_device_params(tr: &mut MgdTrainer) -> Result<Vec<f32>> {
    tr.device_params()
}

fn analog_device_params(tr: &mut AnalogTrainer) -> Result<Vec<f32>> {
    tr.device_params()
}
