//! Hardware imperfection models (§3.5).
//!
//! The paper studies three non-idealities and shows MGD trains through all
//! of them; these are the corresponding injection points:
//!
//! 1. **Cost readout noise** — additive Gaussian on every cost measurement
//!    (`C(t) = C_ideal(t) + N(0, σ_C)`, Fig. 8).  In the paper σ_C is
//!    reported normalized to the perturbation magnitude `|θ̃|`; the
//!    experiment harness performs that normalization, this module works in
//!    absolute units.
//! 2. **Parameter-update noise** — each update gains a Gaussian deviation
//!    (`θ ← θ − ηG + θ_noise`, Eq. 5, Fig. 9), as seen in analog memories
//!    without closed-loop feedback.
//! 3. **Activation defects** — static per-neuron scale/offset on the
//!    sigmoid, `f_k(a) = α_k (1 + e^{−β_k(a−a_k)})^{−1} + b_k`, with
//!    α, β ~ N(1, σ_a) and a, b ~ N(0, σ_a) (Fig. 10).  These are applied
//!    by [`crate::device::NativeDevice`].

use crate::rng::Rng;

/// Stochastic noise configuration for a training run (absolute units).
#[derive(Debug, Clone, Copy, Default)]
pub struct NoiseConfig {
    /// Std-dev of additive Gaussian noise on every cost readout.
    pub sigma_cost: f32,
    /// Std-dev of additive Gaussian noise on every parameter update.
    pub sigma_update: f32,
}

impl NoiseConfig {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_noiseless(&self) -> bool {
        self.sigma_cost == 0.0 && self.sigma_update == 0.0
    }

    /// Sample cost-readout noise for one measurement.
    #[inline]
    pub fn cost_noise(&self, rng: &mut Rng) -> f32 {
        if self.sigma_cost == 0.0 {
            0.0
        } else {
            rng.normal_with(0.0, self.sigma_cost as f64) as f32
        }
    }

    /// Add update noise to a parameter vector in place.
    pub fn apply_update_noise(&self, rng: &mut Rng, theta: &mut [f32]) {
        if self.sigma_update == 0.0 {
            return;
        }
        for v in theta.iter_mut() {
            *v += rng.normal_with(0.0, self.sigma_update as f64) as f32;
        }
    }
}

/// Static per-neuron generalized-logistic defects (Fig. 10).
///
/// `f_k(a) = α_k / (1 + e^{−β_k (a − a_k)}) + b_k`
///
/// The table covers all non-input neurons, layer by layer.  How a defect
/// transforms a non-sigmoid activation is defined by
/// [`crate::device::NativeDevice`]'s executor: `f_k(a) = α_k · act(β_k (a
/// − a_k)) + b_k` elementwise (for sigmoid this *is* the formula above),
/// and for softmax the β/a pair warps the pre-activations while α/b
/// scale-and-offset the resulting probabilities.
#[derive(Debug, Clone, PartialEq)]
pub struct NeuronDefects {
    pub alpha: Vec<f32>,
    pub beta: Vec<f32>,
    pub offset_a: Vec<f32>,
    pub offset_b: Vec<f32>,
}

impl NeuronDefects {
    /// Ideal neurons: α = β = 1, a = b = 0 (plain sigmoid).
    pub fn identity(n_neurons: usize) -> Self {
        NeuronDefects {
            alpha: vec![1.0; n_neurons],
            beta: vec![1.0; n_neurons],
            offset_a: vec![0.0; n_neurons],
            offset_b: vec![0.0; n_neurons],
        }
    }

    /// Sample defective neurons with strength σ_a (paper Fig. 10):
    /// scaling factors α, β ~ N(1, σ_a); offsets a, b ~ N(0, σ_a).
    pub fn sample(n_neurons: usize, sigma_a: f32, rng: &mut Rng) -> Self {
        let s = sigma_a as f64;
        let mut d = NeuronDefects::identity(n_neurons);
        for k in 0..n_neurons {
            d.alpha[k] = rng.normal_with(1.0, s) as f32;
            d.beta[k] = rng.normal_with(1.0, s) as f32;
            d.offset_a[k] = rng.normal_with(0.0, s) as f32;
            d.offset_b[k] = rng.normal_with(0.0, s) as f32;
        }
        d
    }

    /// Evaluate neuron `k`'s defective activation at pre-activation `a`.
    #[inline]
    pub fn activate(&self, k: usize, a: f32) -> f32 {
        let z = self.beta[k] * (a - self.offset_a[k]);
        self.alpha[k] / (1.0 + (-z).exp()) + self.offset_b[k]
    }

    pub fn n_neurons(&self) -> usize {
        self.alpha.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_config_is_exact_zero() {
        let cfg = NoiseConfig::none();
        assert!(cfg.is_noiseless());
        let mut rng = Rng::new(1);
        assert_eq!(cfg.cost_noise(&mut rng), 0.0);
        let mut theta = vec![1.0, 2.0];
        cfg.apply_update_noise(&mut rng, &mut theta);
        assert_eq!(theta, vec![1.0, 2.0]);
    }

    #[test]
    fn cost_noise_statistics() {
        let cfg = NoiseConfig { sigma_cost: 0.5, sigma_update: 0.0 };
        let mut rng = Rng::new(2);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let v = cfg.cost_noise(&mut rng) as f64;
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var.sqrt() - 0.5).abs() < 0.01, "std {}", var.sqrt());
    }

    #[test]
    fn update_noise_perturbs_every_param() {
        let cfg = NoiseConfig { sigma_cost: 0.0, sigma_update: 0.1 };
        let mut rng = Rng::new(3);
        let mut theta = vec![0.0f32; 64];
        cfg.apply_update_noise(&mut rng, &mut theta);
        assert!(theta.iter().all(|&v| v != 0.0));
    }

    #[test]
    fn identity_defects_are_plain_sigmoid() {
        let d = NeuronDefects::identity(3);
        for &a in &[-2.0f32, 0.0, 1.5] {
            let sig = 1.0 / (1.0 + (-a).exp());
            assert!((d.activate(1, a) - sig).abs() < 1e-6);
        }
    }

    #[test]
    fn sampled_defects_have_requested_spread() {
        let mut rng = Rng::new(4);
        let d = NeuronDefects::sample(10_000, 0.2, &mut rng);
        let mean_alpha: f32 = d.alpha.iter().sum::<f32>() / d.alpha.len() as f32;
        let var_alpha: f32 = d.alpha.iter().map(|a| (a - mean_alpha).powi(2)).sum::<f32>()
            / d.alpha.len() as f32;
        assert!((mean_alpha - 1.0).abs() < 0.01, "alpha mean {mean_alpha}");
        assert!((var_alpha.sqrt() - 0.2).abs() < 0.01, "alpha std {}", var_alpha.sqrt());
        let mean_b: f32 = d.offset_b.iter().sum::<f32>() / d.offset_b.len() as f32;
        assert!(mean_b.abs() < 0.01, "offset_b mean {mean_b}");
    }
}
