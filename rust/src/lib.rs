//! # mgd — Multiplexed Gradient Descent for hardware neural networks
//!
//! Rust + JAX + Pallas reproduction of McCaughan et al., *"Multiplexed
//! gradient descent: Fast online training of modern datasets on hardware
//! neural networks without backpropagation"* (2023, DOI 10.1063/5.0157645).
//!
//! The crate is the paper's **L3 coordinator**: a model-free training
//! framework that perturbs the parameters of a black-box inference device,
//! observes only the scalar cost at the device output, extracts the
//! gradient by homodyne detection (Eq. 3), and performs gradient descent
//! (Eq. 4) — no backpropagation anywhere on the request path.
//!
//! Modules:
//!
//! - [`runtime`] — PJRT client; loads AOT HLO artifacts built by
//!   `python/compile/aot.py` (L2 JAX models calling L1 Pallas kernels).
//! - [`device`] — the black-box hardware abstraction ([`device::HardwareDevice`]):
//!   PJRT-backed, pure-Rust native (with per-neuron defects, §3.5), or
//!   remote-over-TCP (chip-in-the-loop, §4/§6).
//! - [`model`] — the typed [`model::ModelSpec`] (dense-layer stack,
//!   per-layer activations, canonical parameter layout, stable
//!   `spec_hash`) shared by devices, the wire protocol, checkpoints,
//!   the CLI and the experiment harnesses.
//! - [`perturb`] — the four perturbation families of §3.4 / Fig. 1c.
//! - [`coordinator`] — Algorithm 1 (discrete), Algorithm 2 (analog), and
//!   the fused on-chip window driver; time constants τp, τθ, τx.
//! - [`optim`] — MGD update rule plus baselines (backprop-SGD, RWC).
//! - [`datasets`] — XOR / n-bit parity / NIST7x7 / synthetic image sets.
//! - [`noise`], [`filters`] — §3.5 imperfection models, analog RC filters.
//! - [`fleet`] — the orchestration layer above `coordinator` and
//!   `device`: a concurrent device pool with leased access, a bounded
//!   priority job scheduler with worker threads, data-parallel MGD with
//!   periodic parameter averaging across replicas (§6's many-copies end
//!   state), and a JSONL telemetry stream.  The pooled TCP server
//!   ([`device::server::serve_pool`]) serves the same pool to remote
//!   chip-in-the-loop trainers.
//! - [`serve`] — the serving side of the north star: a forward-only
//!   [`serve::InferenceEngine`] loaded from a checkpoint (running the
//!   training path's own kernels, [`device::exec`]), dynamic
//!   micro-batching of concurrent requests, a multi-session TCP server
//!   (`mgd serve-infer`, wire opcode `Infer = 0x0C`), and hot checkpoint
//!   reload gated on the model's spec hash.
//! - [`net`] — the unified nonblocking session layer: one epoll-backed
//!   event loop (portable `poll(2)` fallback), a framed-session state
//!   machine with idle/write deadlines and write backpressure, and a
//!   [`net::Service`] dispatch trait.  The device server, the inference
//!   server and the metrics exporter are all implementations riding the
//!   same loop; blocking device work runs on a bounded worker pool, so
//!   thread count is O(workers), not O(sessions).
//! - [`obs`] — live observability: a process-global lock-free metrics
//!   registry (counters, gauges, log-scale histograms, span timers)
//!   instrumenting trainer, exec, fleet and serving layers, exposed via
//!   the wire opcode `Stats = 0x0D`, a Prometheus-text `/metrics` HTTP
//!   listener, and the `mgd top` live dashboard.
//! - [`experiments`] — one harness per paper figure/table (DESIGN.md §5).

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod json;
pub mod par;
pub mod datasets;
pub mod device;
pub mod experiments;
pub mod filters;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod net;
pub mod noise;
pub mod obs;
pub mod optim;
pub mod perturb;
pub mod rng;
pub mod runtime;
pub mod serve;

/// Default artifact directory (relative to the repo root).
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$MGD_ARTIFACT_DIR`, else walk up from
/// the current directory looking for `artifacts/manifest.json`.
pub fn find_artifact_dir() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("MGD_ARTIFACT_DIR") {
        return Ok(std::path::PathBuf::from(dir));
    }
    let mut cur = std::env::current_dir()?;
    loop {
        let candidate = cur.join(DEFAULT_ARTIFACT_DIR);
        if candidate.join("manifest.json").exists() {
            return Ok(candidate);
        }
        if !cur.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found in any parent directory; \
                 run `make artifacts` or set MGD_ARTIFACT_DIR"
            );
        }
    }
}
