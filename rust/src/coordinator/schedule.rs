//! The τx clock: which samples the hardware sees at each timestep.
//!
//! §2.2: "τx controls how often new training samples are shown to the
//! hardware" — and via the ratio τθ/τx it implements mini-batching on
//! hardware that only accepts one sample at a time (Fig. 3).  For devices
//! with native input parallelism B > 1 (Table 2's batch-1000 CNN rows),
//! each window is a B-sample batch instead of a single sample.

use crate::datasets::Dataset;
use crate::rng::{Rng, RngState};

/// How sample windows walk the dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// Deterministic round-robin (the paper's Fig. 3 ordering).
    Cyclic,
    /// Uniform random batches with replacement (SGD-style).
    Random,
}

/// Sample scheduler: produces the index window for each τx period.
#[derive(Debug, Clone)]
pub struct SampleSchedule {
    kind: ScheduleKind,
    n: usize,
    batch: usize,
    cursor: usize,
    rng: Rng,
}

impl SampleSchedule {
    pub fn new(dataset: &Dataset, batch: usize, kind: ScheduleKind, seed: u64) -> Self {
        assert!(dataset.n > 0, "empty dataset");
        SampleSchedule {
            kind,
            n: dataset.n,
            batch,
            cursor: 0,
            rng: Rng::new(seed ^ 0x5343_4845), // "SCHE"
        }
    }

    /// Indices for the next sample window (len = device batch size).
    pub fn next_window(&mut self) -> Vec<usize> {
        let mut idx = Vec::with_capacity(self.batch);
        match self.kind {
            ScheduleKind::Cyclic => {
                for _ in 0..self.batch {
                    idx.push(self.cursor);
                    self.cursor = (self.cursor + 1) % self.n;
                }
            }
            ScheduleKind::Random => {
                for _ in 0..self.batch {
                    idx.push(self.rng.below(self.n as u64) as usize);
                }
            }
        }
        idx
    }

    /// Build the `[T, B]` i32 index tensor for a fused on-chip window:
    /// the sample window advances every `tau_x` steps, exactly as the
    /// discrete loop would drive `load_batch`.
    pub fn window_tensor(&mut self, t_steps: usize, tau_x: u64) -> Vec<i32> {
        let mut out = Vec::with_capacity(t_steps * self.batch);
        let mut current: Vec<usize> = Vec::new();
        for t in 0..t_steps {
            if t as u64 % tau_x.max(1) == 0 || current.is_empty() {
                current = self.next_window();
            }
            out.extend(current.iter().map(|&i| i as i32));
        }
        out
    }

    /// Dataset size this schedule walks.
    pub fn dataset_len(&self) -> usize {
        self.n
    }

    /// Export the mutable state (checkpointing).  The fixed shape —
    /// dataset size, batch, kind — is reproduced by reconstruction.
    pub fn export_state(&self) -> ScheduleState {
        ScheduleState { cursor: self.cursor, rng: self.rng.state() }
    }

    /// Restore an exported state into a freshly constructed schedule.
    pub fn import_state(&mut self, state: &ScheduleState) -> anyhow::Result<()> {
        if state.cursor >= self.n {
            anyhow::bail!(
                "schedule state cursor {} out of range for dataset of {}",
                state.cursor,
                self.n
            );
        }
        self.cursor = state.cursor;
        self.rng.set_state(state.rng);
        Ok(())
    }
}

/// Serializable mutable state of a [`SampleSchedule`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScheduleState {
    pub cursor: usize,
    pub rng: RngState,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::xor;

    #[test]
    fn cyclic_covers_dataset_in_order() {
        let d = xor();
        let mut s = SampleSchedule::new(&d, 1, ScheduleKind::Cyclic, 0);
        let seen: Vec<usize> = (0..8).map(|_| s.next_window()[0]).collect();
        assert_eq!(seen, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn cyclic_batches_wrap() {
        let d = xor();
        let mut s = SampleSchedule::new(&d, 3, ScheduleKind::Cyclic, 0);
        assert_eq!(s.next_window(), vec![0, 1, 2]);
        assert_eq!(s.next_window(), vec![3, 0, 1]);
    }

    #[test]
    fn random_stays_in_range_and_varies() {
        let d = xor();
        let mut s = SampleSchedule::new(&d, 2, ScheduleKind::Random, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..50 {
            for i in s.next_window() {
                assert!(i < d.n);
                seen.insert(i);
            }
        }
        assert_eq!(seen.len(), d.n, "random schedule never hit some samples");
    }

    #[test]
    fn state_roundtrip_resumes_both_kinds() {
        let d = xor();
        for kind in [ScheduleKind::Cyclic, ScheduleKind::Random] {
            let mut a = SampleSchedule::new(&d, 2, kind, 5);
            for _ in 0..7 {
                a.next_window();
            }
            let state = a.export_state();
            let mut b = SampleSchedule::new(&d, 2, kind, 999); // wrong seed on purpose
            b.import_state(&state).unwrap();
            for _ in 0..16 {
                assert_eq!(a.next_window(), b.next_window(), "{kind:?} diverged");
            }
        }
        // Out-of-range cursor is rejected.
        let mut c = SampleSchedule::new(&d, 1, ScheduleKind::Cyclic, 0);
        let bad = ScheduleState { cursor: d.n, rng: c.export_state().rng };
        assert!(c.import_state(&bad).is_err());
    }

    #[test]
    fn window_tensor_respects_tau_x() {
        let d = xor();
        let mut s = SampleSchedule::new(&d, 1, ScheduleKind::Cyclic, 0);
        // τx = 3: sample held for 3 consecutive steps.
        let idx = s.window_tensor(7, 3);
        assert_eq!(idx, vec![0, 0, 0, 1, 1, 1, 2]);
    }

    #[test]
    fn window_tensor_batch_layout() {
        let d = xor();
        let mut s = SampleSchedule::new(&d, 2, ScheduleKind::Cyclic, 0);
        let idx = s.window_tensor(2, 1);
        // step 0 → [0,1], step 1 → [2,3]
        assert_eq!(idx, vec![0, 1, 2, 3]);
    }
}
