//! The fused on-chip MGD driver — the performance path.
//!
//! The paper's end state (§6) is MGD implemented "directly on-chip with
//! local, autonomous circuits": the hardware runs whole stretches of
//! Algorithm 1 by itself, and the external coordinator only sets
//! hyper-parameters, streams data and reads telemetry.  Here the "chip"
//! is the `mgd_scan` AOT artifact: one PJRT call executes T complete MGD
//! timesteps (perturb → measure → homodyne-integrate → update) with the
//! L1 Pallas homodyne kernel inside the loop body.
//!
//! The coordinator keeps the training dataset **resident on the device**
//! ([`crate::runtime::Arg::Resident`]) and ships only the parameter bus,
//! the PRNG seed and the per-window sample schedule per call — the
//! host↔device traffic pattern a real autonomous trainer would have
//! (EXPERIMENTS.md §Perf quantifies the win over per-step calls).
//!
//! On the probe-batching spectrum this driver is the far end: where
//! [`crate::device::HardwareDevice::cost_many`] amortizes K probe costs
//! into one device call while the coordinator still replays Algorithm 1
//! host-side, the fused window runs the *whole* loop body — perturb,
//! measure, integrate, update — on-device for
//! [`OnChipTrainer::probes_per_call`] timesteps at a stretch.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::schedule::{SampleSchedule, ScheduleKind};
use super::{MgdConfig, TrainOptions, TrainResult};
use crate::datasets::Dataset;
use crate::runtime::{Arg, Executable, ResidentBuffer, Runtime, Value};

/// Fused-window MGD trainer over a `mgd_scan` artifact.
pub struct OnChipTrainer<'r> {
    rt: &'r Runtime,
    scan_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    /// Parameter bus (host copy; authoritative between windows).
    pub theta: Vec<f32>,
    /// Gradient integrator carried across windows.
    g: Vec<f32>,
    x_buf: ResidentBuffer,
    y_buf: ResidentBuffer,
    schedule: SampleSchedule,
    cfg: MgdConfig,
    /// T: steps per window (artifact-static).
    window_steps: usize,
    /// B: samples per step (artifact-static).
    scan_batch: usize,
    eval_batch: usize,
    input_shape: Vec<usize>,
    n_outputs: usize,
    steps: u64,
    window_ctr: u32,
}

impl<'r> OnChipTrainer<'r> {
    /// Build a trainer for `model`.  `dataset` is resized (round-robin) to
    /// the artifact's static resident size; `theta` is the initial bus.
    pub fn new(
        rt: &'r Runtime,
        model: &str,
        dataset: &Dataset,
        theta: Vec<f32>,
        cfg: MgdConfig,
    ) -> Result<Self> {
        let meta = rt.manifest.model(model)?.clone();
        if theta.len() != meta.param_count {
            bail!("theta has {} params, model {model} needs {}", theta.len(), meta.param_count);
        }
        let scan_exe = rt
            .executable(&format!("{model}_mgd_scan"))
            .with_context(|| format!("loading mgd_scan artifact for {model}"))?;
        let eval_exe = rt.executable(&format!("{model}_eval"))?;
        let resident = dataset.resize_to(meta.scan_dataset_n);
        let mut x_shape = vec![meta.scan_dataset_n];
        x_shape.extend_from_slice(&meta.input_shape);
        let x_buf = rt.upload(&Value::f32(resident.x.clone(), &x_shape))?;
        let y_buf = rt.upload(&Value::f32(
            resident.y.clone(),
            &[meta.scan_dataset_n, meta.n_outputs],
        ))?;
        let schedule =
            SampleSchedule::new(&resident, meta.scan_batch, ScheduleKind::Cyclic, cfg.seed);
        let p = meta.param_count;
        Ok(OnChipTrainer {
            rt,
            scan_exe,
            eval_exe,
            theta,
            g: vec![0.0; p],
            x_buf,
            y_buf,
            schedule,
            cfg,
            window_steps: meta.scan_steps,
            scan_batch: meta.scan_batch,
            eval_batch: meta.batch_eval,
            input_shape: meta.input_shape.clone(),
            n_outputs: meta.n_outputs,
            steps: 0,
            window_ctr: 0,
        })
    }

    /// Total MGD timesteps executed.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Steps per fused window (the artifact's T).
    pub fn window_steps(&self) -> usize {
        self.window_steps
    }

    /// Perturbation probes evaluated per device call — the fused
    /// analogue of a K-wide [`crate::device::HardwareDevice::cost_many`]
    /// batch (here K is artifact-static and the update rule runs
    /// device-side too).  Lets fleet dashboards report one
    /// "probes/device-call" figure across loop-mode and on-chip
    /// trainers.
    pub fn probes_per_call(&self) -> usize {
        self.window_steps
    }

    /// Current gradient integrator.
    pub fn gradient(&self) -> &[f32] {
        &self.g
    }

    /// Run one fused window of T MGD steps; returns the per-step observed
    /// (perturbed) costs.
    pub fn window(&mut self) -> Result<Vec<f32>> {
        let p = self.theta.len();
        let idx = self.schedule.window_tensor(self.window_steps, self.cfg.tau_x);
        let tau_theta: i32 = if self.cfg.tau_theta == u64::MAX {
            i32::MAX
        } else {
            self.cfg.tau_theta.min(i32::MAX as u64) as i32
        };
        // Window seed: decorrelated per window but reproducible per run.
        let seed = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9)
            .wrapping_add(self.window_ctr as u64) as u32;
        let out = self.scan_exe.run_mixed(
            self.rt.client(),
            &[
                Arg::Host(Value::f32(self.theta.clone(), &[p])),
                Arg::Host(Value::f32(self.g.clone(), &[p])),
                Arg::Host(Value::scalar_u32(seed)),
                Arg::Host(Value::scalar_f32(self.cfg.eta)),
                Arg::Host(Value::scalar_f32(self.cfg.amplitude)),
                Arg::Host(Value::scalar_f32(self.cfg.noise.sigma_cost)),
                Arg::Host(Value::scalar_f32(self.cfg.noise.sigma_update)),
                Arg::Host(Value::scalar_i32(tau_theta)),
                Arg::Host(Value::scalar_i32((self.steps % i32::MAX as u64) as i32)),
                Arg::Resident(&self.x_buf),
                Arg::Resident(&self.y_buf),
                Arg::Host(Value::i32(idx, &[self.window_steps, self.scan_batch])),
            ],
        )?;
        self.theta = out[0].as_f32()?.to_vec();
        self.g = out[1].as_f32()?.to_vec();
        let costs = out[2].as_f32()?.to_vec();
        self.steps += self.window_steps as u64;
        self.window_ctr += 1;
        Ok(costs)
    }

    /// Evaluate (mean cost, accuracy) on a labelled set via the eval
    /// artifact, chunked to its static batch.
    pub fn evaluate(&self, eval: &Dataset) -> Result<(f32, f32)> {
        evaluate_chunked(
            &self.eval_exe,
            &self.theta,
            eval,
            self.eval_batch,
            &self.input_shape,
            self.n_outputs,
        )
    }

    /// Run whole windows until `opts.max_steps` (rounded up to a window)
    /// or a target criterion fires.
    pub fn train(&mut self, opts: &TrainOptions, eval_set: &Dataset) -> Result<TrainResult> {
        let mut result = TrainResult::default();
        while self.steps < opts.max_steps {
            let costs = self.window()?;
            if opts.record_cost_every > 0 {
                let start = self.steps - costs.len() as u64;
                for (i, &c) in costs.iter().enumerate() {
                    let step = start + i as u64;
                    if step % opts.record_cost_every == 0 {
                        result.cost_trace.push((step, c));
                    }
                }
            }
            let eval_due = opts.eval_every > 0
                && (self.steps / opts.eval_every) > ((self.steps - self.window_steps as u64) / opts.eval_every);
            if eval_due {
                let (cost, correct) = self.evaluate(eval_set)?;
                let acc = correct / eval_set.n as f32;
                result.eval_trace.push((self.steps, cost, acc));
                let cost_hit = opts.target_cost.is_some_and(|t| cost < t);
                let acc_hit = opts.target_accuracy.is_some_and(|t| acc >= t);
                if cost_hit || acc_hit {
                    result.solved_at = Some(self.steps);
                    break;
                }
            }
        }
        result.steps_run = self.steps;
        // Two device inferences per fused step (C0 + perturbed).
        result.cost_evals = 2 * self.steps;
        Ok(result)
    }
}

/// Shared chunked-eval helper (also used by experiment harnesses).
pub fn evaluate_chunked(
    exe: &Executable,
    theta: &[f32],
    eval: &Dataset,
    batch: usize,
    input_shape: &[usize],
    n_outputs: usize,
) -> Result<(f32, f32)> {
    let p = theta.len();
    let mut shape = vec![batch];
    shape.extend_from_slice(input_shape);
    let mut total_cost = 0f64;
    let mut total_correct = 0f64;
    let mut done = 0usize;
    while done < eval.n {
        let take = (eval.n - done).min(batch);
        let idx: Vec<usize> = (0..batch).map(|j| done + (j % take)).collect();
        let (xb, yb) = eval.gather(&idx);
        let out = exe.run(&[
            Value::f32(theta.to_vec(), &[p]),
            Value::f32(xb, &shape),
            Value::f32(yb, &[batch, n_outputs]),
        ])?;
        total_cost += out[0].to_scalar_f32()? as f64 * take as f64;
        total_correct += out[1].to_scalar_f32()? as f64 * take as f64 / batch as f64;
        done += take;
    }
    Ok((
        (total_cost / eval.n as f64) as f32,
        total_correct as f32,
    ))
}
