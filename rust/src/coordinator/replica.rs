//! Replica statistics: fan a training run across many random seeds.
//!
//! Every figure in the paper reports statistics over random network
//! initializations (100–1000 replicas).  Each replica here gets an
//! independent seed (init, perturbations, schedule, noise).  Execution is
//! a thin wrapper over the fleet scheduler's scoped batch engine
//! ([`crate::fleet::run_batch`]) — replica statistics and the production
//! training farm share one queue/worker code path.  NativeDevice replicas
//! are embarrassingly parallel; PJRT-backed runs should use
//! `parallel = false` (the CPU client is a shared, internally-threaded
//! resource).

use anyhow::Result;

use super::TrainResult;
use crate::fleet::run_batch;
use crate::par::default_workers;

/// One replica's outcome.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    pub seed: u64,
    pub result: TrainResult,
}

/// Run `n_replicas` independent trainings of `run(seed)`.
///
/// Replica seeds are `base_seed + i`.  Failures propagate (a replica
/// erroring is a bug, not a statistic).  Replicas are scheduled as one
/// batch of fleet jobs: `parallel = true` fans them over
/// [`default_workers`] scoped workers, `parallel = false` pins the batch
/// to one worker (strictly sequential, in seed order).
pub fn replica_stats<F>(
    n_replicas: usize,
    base_seed: u64,
    parallel: bool,
    run: F,
) -> Result<Vec<ReplicaOutcome>>
where
    F: Fn(u64) -> Result<TrainResult> + Sync + Send,
{
    let seeds: Vec<u64> = (0..n_replicas as u64).map(|i| base_seed + i).collect();
    let workers = if parallel { default_workers(n_replicas) } else { 1 };
    let run = &run;
    let jobs: Vec<_> = seeds.iter().map(|&seed| move || run(seed)).collect();
    seeds
        .iter()
        .zip(run_batch(workers, jobs))
        .map(|(&seed, r)| r.map(|result| ReplicaOutcome { seed, result }))
        .collect()
}

/// Fraction of replicas that met their target.
pub fn converged_fraction(outcomes: &[ReplicaOutcome]) -> f64 {
    if outcomes.is_empty() {
        return 0.0;
    }
    outcomes.iter().filter(|o| o.result.solved()).count() as f64 / outcomes.len() as f64
}

/// Solve times (steps) of the replicas that converged.
pub fn solve_times(outcomes: &[ReplicaOutcome]) -> Vec<u64> {
    let mut times: Vec<u64> =
        outcomes.iter().filter_map(|o| o.result.solved_at).collect();
    times.sort_unstable();
    times
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake(solved_at: Option<u64>) -> TrainResult {
        TrainResult { solved_at, steps_run: 100, ..Default::default() }
    }

    #[test]
    fn stats_aggregate() {
        let outcomes = replica_stats(4, 10, true, |seed| {
            Ok(fake(if seed % 2 == 0 { Some(seed * 10) } else { None }))
        })
        .unwrap();
        assert_eq!(outcomes.len(), 4);
        assert_eq!(converged_fraction(&outcomes), 0.5);
        assert_eq!(solve_times(&outcomes), vec![100, 120]);
    }

    #[test]
    fn seeds_are_distinct_and_ordered() {
        let outcomes = replica_stats(3, 5, false, |seed| Ok(fake(Some(seed)))).unwrap();
        let seeds: Vec<u64> = outcomes.iter().map(|o| o.seed).collect();
        assert_eq!(seeds, vec![5, 6, 7]);
    }

    #[test]
    fn errors_propagate() {
        let res = replica_stats(2, 0, false, |seed| {
            if seed == 1 {
                anyhow::bail!("boom");
            }
            Ok(fake(None))
        });
        assert!(res.is_err());
    }
}
