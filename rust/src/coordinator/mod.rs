//! The MGD coordination layer — the paper's system contribution.
//!
//! Three training drivers share the same configuration language (the three
//! time constants of §2.2) and the same black-box device interface:
//!
//! - [`discrete`] — Algorithm 1, literally: one device cost-evaluation per
//!   timestep, baseline-cost caching, any perturbation family.  This is
//!   the *chip-in-the-loop* mode and the reference semantics.
//! - [`analog`] — Algorithm 2: continuous-time emulation with sinusoidal
//!   perturbations, a highpass filter extracting C̃ at the output and a
//!   per-parameter lowpass bank integrating G (Fig. 2d).
//! - [`onchip`] — the fused `mgd_scan` artifact: whole τθ-windows of
//!   Algorithm 1 execute inside one PJRT call (the paper's §6 "local,
//!   autonomous circuits" end state).  Identical update rule; this is the
//!   performance path used for the Table 2 datasets.
//!
//! [`schedule`] owns the τx clock (when samples change) and batch
//! assembly; [`replica`] fans a training run across many random
//! initializations for the paper's statistics; [`checkpoint`] serializes
//! the discrete trainer's complete state to versioned on-disk snapshots
//! with a bit-identical resume guarantee (long runs survive crashes).

pub mod analog;
pub mod checkpoint;
pub mod discrete;
pub mod onchip;
pub mod replica;
pub mod schedule;

pub use analog::AnalogTrainer;
pub use checkpoint::{
    checkpoint_path, load_snapshot, prune_dp_rounds, save_snapshot, train_checkpointed,
    CheckpointConfig, TrainerSnapshot,
};
pub use discrete::{MgdTrainer, StepOutput};
pub use onchip::OnChipTrainer;
pub use replica::{converged_fraction, replica_stats, solve_times, ReplicaOutcome};
pub use schedule::{SampleSchedule, ScheduleKind, ScheduleState};

use crate::noise::NoiseConfig;
use crate::perturb::PerturbKind;

/// The MGD hyper-parameters of §2.2 — three time constants plus the
/// perturbation family, learning rate and amplitude.
#[derive(Debug, Clone, Copy)]
pub struct MgdConfig {
    /// τx: timesteps between training-sample changes.
    pub tau_x: u64,
    /// τθ: timesteps between parameter updates (gradient-integration time).
    /// `u64::MAX` = integrate forever (Fig. 5 mode).
    pub tau_theta: u64,
    /// τp: timesteps between perturbation-pattern changes.
    pub tau_p: u64,
    /// η: learning rate (Eq. 4).
    pub eta: f32,
    /// Δθ: perturbation amplitude.
    pub amplitude: f32,
    /// Perturbation family (Fig. 1c).
    pub kind: PerturbKind,
    /// Hardware noise injection (§3.5).
    pub noise: NoiseConfig,
    /// Seed for perturbations, schedules and noise.
    pub seed: u64,
}

impl Default for MgdConfig {
    fn default() -> Self {
        MgdConfig {
            tau_x: 1,
            tau_theta: 1,
            tau_p: 1,
            eta: 1.0,
            amplitude: 0.01,
            kind: PerturbKind::RademacherCode,
            noise: NoiseConfig::none(),
            seed: 0,
        }
    }
}

impl MgdConfig {
    /// Effective batch size as defined in §2.2: τθ/τx (how many distinct
    /// sample windows are integrated into one update), floored at 1.
    pub fn effective_batch_ratio(&self) -> u64 {
        if self.tau_theta == u64::MAX {
            return u64::MAX;
        }
        (self.tau_theta / self.tau_x.max(1)).max(1)
    }
}

/// Stopping / recording options shared by all trainers.
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Hard step budget.
    pub max_steps: u64,
    /// Record the observed (perturbed) cost every k steps (0 = never).
    pub record_cost_every: u64,
    /// Evaluate on the eval set every k steps (0 = never).
    pub eval_every: u64,
    /// Stop once the *full-dataset* cost falls below this (Fig. 6/7's
    /// "solved" criterion, checked at `eval_every` cadence).
    pub target_cost: Option<f32>,
    /// Stop once eval accuracy reaches this fraction (Fig. 8's criterion).
    pub target_accuracy: Option<f32>,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            max_steps: 100_000,
            record_cost_every: 0,
            eval_every: 0,
            target_cost: None,
            target_accuracy: None,
        }
    }
}

/// Output of a training run.
#[derive(Debug, Clone, Default)]
pub struct TrainResult {
    /// Steps actually executed.
    pub steps_run: u64,
    /// Step at which the target criterion was met, if it was.
    pub solved_at: Option<u64>,
    /// (step, observed cost) samples.
    pub cost_trace: Vec<(u64, f32)>,
    /// (step, eval cost, eval accuracy) samples.
    pub eval_trace: Vec<(u64, f32, f32)>,
    /// Total device cost-evaluations (perturbed + baseline measurements) —
    /// the paper's hardware-time unit.
    pub cost_evals: u64,
}

impl TrainResult {
    /// Final recorded accuracy, if any eval ran.
    pub fn final_accuracy(&self) -> Option<f32> {
        self.eval_trace.last().map(|&(_, _, acc)| acc)
    }

    /// Whether the run met its target.
    pub fn solved(&self) -> bool {
        self.solved_at.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_ratio() {
        let mut cfg = MgdConfig { tau_theta: 4, tau_x: 1, ..Default::default() };
        assert_eq!(cfg.effective_batch_ratio(), 4);
        cfg.tau_x = 4;
        assert_eq!(cfg.effective_batch_ratio(), 1);
        cfg.tau_theta = u64::MAX;
        assert_eq!(cfg.effective_batch_ratio(), u64::MAX);
        cfg.tau_theta = 1;
        cfg.tau_x = 250;
        assert_eq!(cfg.effective_batch_ratio(), 1, "floors at 1");
    }
}
