//! Deterministic checkpoint/resume for the discrete MGD trainer.
//!
//! A long CIFAR-scale chip-in-the-loop run — the regime the scaling
//! follow-up (Oripov et al., 2025) identifies as where perturbative
//! training pays off — dies with its process unless its state survives on
//! disk.  This module serializes an [`MgdTrainer`]'s complete state to a
//! **versioned JSON checkpoint** via the in-repo [`crate::json`]
//! substrate, and drives chunked training with periodic checkpoints and
//! checkpoint-on-failure.
//!
//! # Bit-exactness
//!
//! The resume contract is the strongest one MGD admits: crash at step *k*
//! + restore replays **bit-identically** to an uninterrupted run — same
//! θ, same G, same noise-draw order, same `cost_evals` (the contract
//! `step_window` established for probe batching, extended across process
//! boundaries).  JSON's only numeric type is f64, which cannot hold every
//! `u64` (53-bit mantissa) and would round-trip floats through decimal
//! formatting, so the encoding never relies on it for exactness:
//!
//! - `f32` values are stored as their **bit pattern** (`u32`, exact in
//!   f64) — NaN/∞-safe, no decimal round trip.
//! - `f64` values (the sinusoidal phasor state) are stored as their bit
//!   pattern in a **decimal string**.
//! - `u64` counters and RNG words are stored as **decimal strings**.
//!
//! # What a checkpoint captures — and what it does not
//!
//! Captured: the trainer config (echoed and validated on restore), θ
//! (read from the device), G, the cached baseline C₀ and its validity,
//! the loaded sample window, step/cost-eval counters, the full noise-RNG
//! state, the sample-schedule state and the perturbation-generator state
//! (including the Rademacher pattern + RNG and the sinusoidal phasors,
//! whose recurrence would otherwise drift from a direct re-evaluation).
//!
//! Not captured: device *internals* (activation-defect tables, remote
//! addresses) — devices are rebuilt by the caller exactly as they were
//! built originally — and accumulated cost/eval traces, which restart at
//! resume (the paper's figures are traces; the training state is θ/G).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::datasets::Dataset;
use crate::json::Json;
use crate::perturb::{PerturbKind, PerturbState};
use crate::rng::RngState;

use super::discrete::MgdTrainer;
use super::schedule::ScheduleState;
use super::{MgdConfig, TrainOptions, TrainResult};

use std::collections::BTreeMap;

/// Format tag of a trainer checkpoint file.
pub const CHECKPOINT_FORMAT: &str = "mgd-trainer-checkpoint";
/// Current checkpoint schema version.  Bump on any schema change;
/// versions newer than this build are rejected with a clear error rather
/// than misread, and versions back to [`CHECKPOINT_MIN_VERSION`] load
/// under a documented compat rule.
///
/// **v2** (this build) embeds the model identity: `model` (the canonical
/// [`crate::model::ModelSpec`] string) and `spec_hash` (its stable
/// [`crate::model::ModelSpec::spec_hash`]), both `null` when the device
/// is a spec-less black box.
///
/// **v1 compat rule**: v1 checkpoints predate spec identity — they load
/// with `model`/`spec_hash` as `None`, and restore skips the spec-hash
/// gate (the parameter-count check remains the only shape gate, exactly
/// the v1 guarantee).  A v1 file can therefore restore into a *wrong*
/// same-P model; re-checkpointing immediately rewrites it as v2 with the
/// identity embedded.
pub const CHECKPOINT_VERSION: u64 = 2;
/// Oldest checkpoint schema this build still reads.
pub const CHECKPOINT_MIN_VERSION: u64 = 1;
/// Format tag of a data-parallel run's meta file.
pub const DP_META_FORMAT: &str = "mgd-dp-checkpoint";

/// The complete serializable state of an [`MgdTrainer`] (see
/// [`MgdTrainer::checkpoint`] / [`MgdTrainer::restore`]).
#[derive(Debug, Clone)]
pub struct TrainerSnapshot {
    /// Config echo, validated field-by-field on restore.
    pub config: MgdConfig,
    pub n_params: usize,
    /// Device parameter memory at snapshot time.
    pub theta: Vec<f32>,
    /// Gradient integrator G.
    pub g: Vec<f32>,
    /// Currently loaded sample window (empty before the first step).
    pub xb: Vec<f32>,
    pub yb: Vec<f32>,
    /// Cached baseline cost C₀ and its validity.
    pub c0: f32,
    pub c0_valid: bool,
    /// First step at/after which a sample-window load is due (the
    /// crash-consistency watermark for the sample schedule).
    pub next_load_step: u64,
    pub step: u64,
    pub cost_evals: u64,
    /// Noise/update RNG, mid-stream.
    pub rng: RngState,
    /// Sample-schedule cursor + RNG.
    pub schedule: ScheduleState,
    /// Perturbation-generator state.
    pub pert: PerturbState,
    /// Canonical model-spec string of the device at snapshot time
    /// (`None`: spec-less device, or a v1 checkpoint).
    pub model: Option<String>,
    /// Stable spec hash matching `model` — what restore validates
    /// against the live device's spec.
    pub spec_hash: Option<u64>,
    /// Antithetic pairing state: the even step's measured `C⁺` when the
    /// snapshot was taken mid-pair (`None` otherwise, and always for the
    /// forward-difference families).  Absent in pre-engine v2 files —
    /// read as `None`.
    pub pending_c: Option<f32>,
    /// Per-layer learning-rate multipliers of the installed
    /// [`crate::perturb::PerLayerSchedule`] (empty = no schedule; absent
    /// in pre-engine v2 files — read as empty).  Restore requires the
    /// live trainer's schedule to match bit-exactly, like every other
    /// config field.
    pub layer_lr: Vec<f32>,
    /// Per-layer amplitude multipliers (see `layer_lr`).
    pub layer_amp: Vec<f32>,
}

// ---------------------------------------------------------------------------
// Exact JSON encodings
// ---------------------------------------------------------------------------

fn ju64(v: u64) -> Json {
    Json::Str(v.to_string())
}

fn pu64(j: &Json) -> Result<u64> {
    j.as_str()
        .context("expected a decimal-string u64")?
        .parse::<u64>()
        .context("malformed u64 string")
}

fn jopt_u64(v: Option<u64>) -> Json {
    match v {
        Some(v) => ju64(v),
        None => Json::Null,
    }
}

fn popt_u64(j: &Json) -> Result<Option<u64>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(pu64(other)?)),
    }
}

fn jf32(v: f32) -> Json {
    Json::Num(v.to_bits() as f64)
}

fn pf32(j: &Json) -> Result<f32> {
    let bits = j.as_f64()?;
    if bits < 0.0 || bits.fract() != 0.0 || bits > u32::MAX as f64 {
        bail!("f32 bit pattern out of range: {bits}");
    }
    Ok(f32::from_bits(bits as u32))
}

fn jf32_arr(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&v| jf32(v)).collect())
}

fn pf32_arr(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?.iter().map(pf32).collect()
}

fn jf64(v: f64) -> Json {
    ju64(v.to_bits())
}

fn pf64(j: &Json) -> Result<f64> {
    Ok(f64::from_bits(pu64(j)?))
}

fn jf64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| jf64(v)).collect())
}

fn pf64_arr(j: &Json) -> Result<Vec<f64>> {
    j.as_arr()?.iter().map(pf64).collect()
}

fn rng_to_json(state: &RngState) -> Json {
    let mut m = BTreeMap::new();
    m.insert("s".to_string(), Json::Arr(state.s.iter().map(|&w| ju64(w)).collect()));
    m.insert(
        "gauss_spare".to_string(),
        match state.gauss_spare {
            Some(v) => jf64(v),
            None => Json::Null,
        },
    );
    Json::Obj(m)
}

fn rng_from_json(j: &Json) -> Result<RngState> {
    let words = j.field("s")?.as_arr()?;
    if words.len() != 4 {
        bail!("RNG state needs 4 words, got {}", words.len());
    }
    let mut s = [0u64; 4];
    for (dst, w) in s.iter_mut().zip(words) {
        *dst = pu64(w)?;
    }
    let gauss_spare = match j.field("gauss_spare")? {
        Json::Null => None,
        other => Some(pf64(other)?),
    };
    Ok(RngState { s, gauss_spare })
}

fn config_to_json(cfg: &MgdConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("tau_x".to_string(), ju64(cfg.tau_x));
    m.insert("tau_theta".to_string(), ju64(cfg.tau_theta));
    m.insert("tau_p".to_string(), ju64(cfg.tau_p));
    m.insert("eta".to_string(), jf32(cfg.eta));
    m.insert("amplitude".to_string(), jf32(cfg.amplitude));
    m.insert("kind".to_string(), Json::Str(cfg.kind.token()));
    m.insert("sigma_cost".to_string(), jf32(cfg.noise.sigma_cost));
    m.insert("sigma_update".to_string(), jf32(cfg.noise.sigma_update));
    m.insert("seed".to_string(), ju64(cfg.seed));
    Json::Obj(m)
}

fn config_from_json(j: &Json) -> Result<MgdConfig> {
    Ok(MgdConfig {
        tau_x: pu64(j.field("tau_x")?)?,
        tau_theta: pu64(j.field("tau_theta")?)?,
        tau_p: pu64(j.field("tau_p")?)?,
        eta: pf32(j.field("eta")?)?,
        amplitude: pf32(j.field("amplitude")?)?,
        kind: j.field("kind")?.as_str()?.parse::<PerturbKind>()?,
        noise: crate::noise::NoiseConfig {
            sigma_cost: pf32(j.field("sigma_cost")?)?,
            sigma_update: pf32(j.field("sigma_update")?)?,
        },
        seed: pu64(j.field("seed")?)?,
    })
}

fn pert_to_json(state: &PerturbState) -> Json {
    let mut m = BTreeMap::new();
    m.insert(
        "rng".to_string(),
        match &state.rng {
            Some(rng) => rng_to_json(rng),
            None => Json::Null,
        },
    );
    m.insert("current".to_string(), jf32_arr(&state.current));
    m.insert("current_window".to_string(), jopt_u64(state.current_window));
    m.insert("sin".to_string(), jf64_arr(&state.sin));
    m.insert("cos".to_string(), jf64_arr(&state.cos));
    m.insert("state_t".to_string(), jopt_u64(state.state_t));
    Json::Obj(m)
}

fn pert_from_json(j: &Json) -> Result<PerturbState> {
    Ok(PerturbState {
        rng: match j.field("rng")? {
            Json::Null => None,
            other => Some(rng_from_json(other)?),
        },
        current: pf32_arr(j.field("current")?)?,
        current_window: popt_u64(j.field("current_window")?)?,
        sin: pf64_arr(j.field("sin")?)?,
        cos: pf64_arr(j.field("cos")?)?,
        state_t: popt_u64(j.field("state_t")?)?,
    })
}

/// Field-by-field config equality (f32 fields compared by bit pattern),
/// with the first mismatching field named in the error — restoring a
/// checkpoint into a differently-configured trainer would not crash, it
/// would silently train a different trajectory, which is worse.
pub fn ensure_config_matches(live: &MgdConfig, saved: &MgdConfig) -> Result<()> {
    let mismatch = |field: &str, live: String, saved: String| -> Result<()> {
        bail!("checkpoint config mismatch on {field}: trainer has {live}, checkpoint has {saved}")
    };
    if live.tau_x != saved.tau_x {
        return mismatch("tau_x", live.tau_x.to_string(), saved.tau_x.to_string());
    }
    if live.tau_theta != saved.tau_theta {
        return mismatch("tau_theta", live.tau_theta.to_string(), saved.tau_theta.to_string());
    }
    if live.tau_p != saved.tau_p {
        return mismatch("tau_p", live.tau_p.to_string(), saved.tau_p.to_string());
    }
    if live.eta.to_bits() != saved.eta.to_bits() {
        return mismatch("eta", live.eta.to_string(), saved.eta.to_string());
    }
    if live.amplitude.to_bits() != saved.amplitude.to_bits() {
        return mismatch("amplitude", live.amplitude.to_string(), saved.amplitude.to_string());
    }
    if live.kind != saved.kind {
        return mismatch("kind", live.kind.token(), saved.kind.token());
    }
    if live.noise.sigma_cost.to_bits() != saved.noise.sigma_cost.to_bits() {
        return mismatch(
            "sigma_cost",
            live.noise.sigma_cost.to_string(),
            saved.noise.sigma_cost.to_string(),
        );
    }
    if live.noise.sigma_update.to_bits() != saved.noise.sigma_update.to_bits() {
        return mismatch(
            "sigma_update",
            live.noise.sigma_update.to_string(),
            saved.noise.sigma_update.to_string(),
        );
    }
    if live.seed != saved.seed {
        return mismatch("seed", live.seed.to_string(), saved.seed.to_string());
    }
    Ok(())
}

impl TrainerSnapshot {
    /// Serialize to the versioned checkpoint document.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("format".to_string(), Json::Str(CHECKPOINT_FORMAT.to_string()));
        m.insert("version".to_string(), Json::Num(CHECKPOINT_VERSION as f64));
        m.insert("config".to_string(), config_to_json(&self.config));
        m.insert("n_params".to_string(), Json::Num(self.n_params as f64));
        m.insert("step".to_string(), ju64(self.step));
        m.insert("cost_evals".to_string(), ju64(self.cost_evals));
        m.insert("next_load_step".to_string(), ju64(self.next_load_step));
        m.insert("c0".to_string(), jf32(self.c0));
        m.insert("c0_valid".to_string(), Json::Bool(self.c0_valid));
        m.insert("theta".to_string(), jf32_arr(&self.theta));
        m.insert("g".to_string(), jf32_arr(&self.g));
        m.insert("xb".to_string(), jf32_arr(&self.xb));
        m.insert("yb".to_string(), jf32_arr(&self.yb));
        m.insert("rng".to_string(), rng_to_json(&self.rng));
        let mut sched = BTreeMap::new();
        sched.insert("cursor".to_string(), Json::Num(self.schedule.cursor as f64));
        sched.insert("rng".to_string(), rng_to_json(&self.schedule.rng));
        m.insert("schedule".to_string(), Json::Obj(sched));
        m.insert("pert".to_string(), pert_to_json(&self.pert));
        m.insert(
            "model".to_string(),
            match &self.model {
                Some(s) => Json::Str(s.clone()),
                None => Json::Null,
            },
        );
        m.insert("spec_hash".to_string(), jopt_u64(self.spec_hash));
        m.insert(
            "pending_c".to_string(),
            match self.pending_c {
                Some(c) => jf32(c),
                None => Json::Null,
            },
        );
        m.insert("layer_lr".to_string(), jf32_arr(&self.layer_lr));
        m.insert("layer_amp".to_string(), jf32_arr(&self.layer_amp));
        Json::Obj(m)
    }

    /// Parse a versioned checkpoint document (v1 or v2; see
    /// [`CHECKPOINT_VERSION`] for the v1 compat rule).
    pub fn from_json(j: &Json) -> Result<TrainerSnapshot> {
        let format = j.field("format")?.as_str()?;
        if format != CHECKPOINT_FORMAT {
            bail!("not a trainer checkpoint (format {format:?})");
        }
        let version = j.field("version")?.as_u64()?;
        if !(CHECKPOINT_MIN_VERSION..=CHECKPOINT_VERSION).contains(&version) {
            bail!(
                "checkpoint version {version} is not supported (this build reads \
                 versions {CHECKPOINT_MIN_VERSION}..={CHECKPOINT_VERSION})"
            );
        }
        // v1 compat: the spec-identity fields do not exist; load them as
        // None so restore skips the spec gate.
        let (model, spec_hash) = if version >= 2 {
            let model = match j.field("model")? {
                Json::Null => None,
                other => Some(other.as_str()?.to_string()),
            };
            (model, popt_u64(j.field("spec_hash")?)?)
        } else {
            (None, None)
        };
        // Scaling-engine fields were added mid-v2; files written before
        // them simply omit the keys, which reads as "no antithetic pair
        // in flight, no per-layer schedule" — exactly the state those
        // trainers were in.
        let pending_c = match j.field("pending_c") {
            Ok(Json::Null) | Err(_) => None,
            Ok(other) => Some(pf32(other)?),
        };
        let layer_lr = match j.field("layer_lr") {
            Ok(v) => pf32_arr(v)?,
            Err(_) => Vec::new(),
        };
        let layer_amp = match j.field("layer_amp") {
            Ok(v) => pf32_arr(v)?,
            Err(_) => Vec::new(),
        };
        let sched = j.field("schedule")?;
        Ok(TrainerSnapshot {
            config: config_from_json(j.field("config")?)?,
            n_params: j.field("n_params")?.as_usize()?,
            theta: pf32_arr(j.field("theta")?)?,
            g: pf32_arr(j.field("g")?)?,
            xb: pf32_arr(j.field("xb")?)?,
            yb: pf32_arr(j.field("yb")?)?,
            c0: pf32(j.field("c0")?)?,
            c0_valid: j.field("c0_valid")?.as_bool()?,
            next_load_step: pu64(j.field("next_load_step")?)?,
            step: pu64(j.field("step")?)?,
            cost_evals: pu64(j.field("cost_evals")?)?,
            rng: rng_from_json(j.field("rng")?)?,
            schedule: ScheduleState {
                cursor: sched.field("cursor")?.as_usize()?,
                rng: rng_from_json(sched.field("rng")?)?,
            },
            pert: pert_from_json(j.field("pert")?)?,
            model,
            spec_hash,
            pending_c,
            layer_lr,
            layer_amp,
        })
    }
}

// ---------------------------------------------------------------------------
// Files
// ---------------------------------------------------------------------------

/// Write a JSON document atomically: temp file in the same directory,
/// then rename.  A crash mid-write leaves the previous checkpoint
/// intact — a torn checkpoint is worse than a stale one.
fn write_json_atomic(path: &Path, doc: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating checkpoint dir {}", parent.display()))?;
        }
    }
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(".tmp");
    let tmp = PathBuf::from(tmp);
    std::fs::write(&tmp, format!("{}\n", doc.dump()))
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    Ok(())
}

fn read_json_file(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing checkpoint {}", path.display()))
}

/// Canonical checkpoint file inside a checkpoint directory.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("checkpoint.json")
}

/// Save a snapshot to `path` (atomically).
pub fn save_snapshot(path: &Path, snap: &TrainerSnapshot) -> Result<()> {
    let _t = crate::obs::span("mgd_checkpoint_save_seconds");
    write_json_atomic(path, &snap.to_json())?;
    crate::obs::counter("mgd_checkpoints_total").inc();
    Ok(())
}

/// Load a snapshot from `path`.
pub fn load_snapshot(path: &Path) -> Result<TrainerSnapshot> {
    TrainerSnapshot::from_json(&read_json_file(path)?)
        .with_context(|| format!("decoding checkpoint {}", path.display()))
}

/// Meta file recording a data-parallel run's completed-round watermark.
pub fn dp_meta_path(dir: &Path) -> PathBuf {
    dir.join("dp-meta.json")
}

/// Per-replica snapshot file of a data-parallel run, one per completed
/// round.  Round-stamped names are what make the meta commit safe: a
/// crash *between* the replica saves for round r+1 and the meta commit
/// leaves the round-r files (the meta's resume point) untouched —
/// overwriting in place would destroy the only consistent snapshot set.
/// Files older than the committed round are garbage-collected after
/// each commit; files newer than the meta are simply ignored on resume.
pub fn dp_replica_path(dir: &Path, replica: usize, rounds_done: u64) -> PathBuf {
    dir.join(format!("dp-replica-{replica}-round-{rounds_done}.json"))
}

/// Record that every replica checkpoint for `rounds_done` completed
/// rounds is on disk.  Written *after* the replica files (a meta
/// pointing at missing replica files would be a lie).
pub fn save_dp_meta(dir: &Path, rounds_done: u64, replicas: usize) -> Result<()> {
    let mut m = BTreeMap::new();
    m.insert("format".to_string(), Json::Str(DP_META_FORMAT.to_string()));
    m.insert("version".to_string(), Json::Num(CHECKPOINT_VERSION as f64));
    m.insert("rounds_done".to_string(), ju64(rounds_done));
    m.insert("replicas".to_string(), Json::Num(replicas as f64));
    write_json_atomic(&dp_meta_path(dir), &Json::Obj(m))
}

/// Read a data-parallel meta file: `Ok(None)` if absent (fresh run),
/// `Ok(Some((rounds_done, replicas)))` if present.
pub fn load_dp_meta(dir: &Path) -> Result<Option<(u64, usize)>> {
    let path = dp_meta_path(dir);
    if !path.exists() {
        return Ok(None);
    }
    let j = read_json_file(&path)?;
    let format = j.field("format")?.as_str()?;
    if format != DP_META_FORMAT {
        bail!("{} is not a data-parallel meta file (format {format:?})", path.display());
    }
    let version = j.field("version")?.as_u64()?;
    if !(CHECKPOINT_MIN_VERSION..=CHECKPOINT_VERSION).contains(&version) {
        bail!(
            "dp meta version {version} unsupported (this build reads \
             {CHECKPOINT_MIN_VERSION}..={CHECKPOINT_VERSION})"
        );
    }
    Ok(Some((pu64(j.field("rounds_done")?)?, j.field("replicas")?.as_usize()?)))
}

/// Garbage-collect superseded round-stamped replica snapshots, keeping
/// the most recent `keep` committed rounds (`keep` ≥ 1; the committed
/// round itself is never deleted).  Returns the number of files removed.
///
/// Crash safety: this runs only **after** the round meta commits, and it
/// works from a directory listing rather than a remembered round number —
/// so a crash *during* a previous GC (which leaves a partial set of
/// stale files) is healed by the next call, and a crash during *this*
/// call deletes only files already outside the keep window.  The meta's
/// resume point is untouched at every instant.
pub fn prune_dp_rounds(dir: &Path, committed_round: u64, keep: u64) -> Result<usize> {
    let keep = keep.max(1);
    // Rounds strictly below this are garbage.
    let floor = committed_round.saturating_sub(keep - 1);
    let mut removed = 0usize;
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing checkpoint dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        // dp-replica-{i}-round-{r}.json
        let Some(rest) = name.strip_prefix("dp-replica-") else { continue };
        let Some(rest) = rest.strip_suffix(".json") else { continue };
        let Some((_, round)) = rest.split_once("-round-") else { continue };
        let Ok(round) = round.parse::<u64>() else { continue };
        if round < floor {
            std::fs::remove_file(entry.path())
                .with_context(|| format!("pruning {}", entry.path().display()))?;
            removed += 1;
        }
    }
    Ok(removed)
}

// ---------------------------------------------------------------------------
// Chunked training driver
// ---------------------------------------------------------------------------

/// Checkpointing knobs for [`train_checkpointed`].
#[derive(Debug, Clone)]
pub struct CheckpointConfig {
    /// Directory holding `checkpoint.json`.
    pub dir: PathBuf,
    /// Checkpoint every this many steps (0 = only at completion/failure).
    pub every_steps: u64,
    /// Restore from an existing checkpoint before training (absence is
    /// not an error — a fresh run simply starts at step 0).
    pub resume: bool,
}

/// [`MgdTrainer::train_batched`] in checkpointed chunks.
///
/// The trajectory is bit-identical to a single uninterrupted
/// `train_batched` call for *any* chunking (chunk boundaries are just
/// `step_window` boundaries, which the PR 2 contract makes invisible),
/// so crash-anywhere + `resume` lands on the same θ/G/cost_evals.  On a
/// training error the current state is checkpointed best-effort before
/// the error propagates, so a crashed farm job resumes from the failure
/// point instead of step 0 — and a retried job on another device picks
/// that checkpoint up automatically.
///
/// Traces (`cost_trace`, `eval_trace`) cover this invocation only;
/// counters (`steps_run`, `cost_evals`) are cumulative across resumes.
pub fn train_checkpointed(
    trainer: &mut MgdTrainer,
    opts: &TrainOptions,
    eval_set: Option<&Dataset>,
    probes_per_call: usize,
    ck: &CheckpointConfig,
) -> Result<TrainResult> {
    let path = checkpoint_path(&ck.dir);
    if ck.resume && path.exists() {
        let snap = load_snapshot(&path)?;
        trainer
            .restore(&snap)
            .with_context(|| format!("restoring checkpoint {}", path.display()))?;
    }
    let mut merged = TrainResult::default();
    let mut saved_at: Option<u64> = None;
    while trainer.steps() < opts.max_steps {
        let steps = trainer.steps();
        let target = if ck.every_steps == 0 {
            opts.max_steps
        } else {
            ((steps / ck.every_steps + 1) * ck.every_steps).min(opts.max_steps)
        };
        let chunk_opts = TrainOptions { max_steps: target, ..opts.clone() };
        match trainer.train_batched(&chunk_opts, eval_set, probes_per_call) {
            Ok(r) => {
                merged.cost_trace.extend(r.cost_trace);
                merged.eval_trace.extend(r.eval_trace);
                let snap = trainer.checkpoint()?;
                save_snapshot(&path, &snap)?;
                saved_at = Some(trainer.steps());
                if r.solved_at.is_some() {
                    merged.solved_at = r.solved_at;
                    break;
                }
            }
            Err(e) => {
                // Checkpoint-on-failure: salvage the exact pre-error
                // state (consistent — a failed window mutates nothing
                // past the last completed algorithm event).  Best
                // effort: the original error is what must surface.
                if let Err(save_err) =
                    trainer.checkpoint().and_then(|snap| save_snapshot(&path, &snap))
                {
                    eprintln!(
                        "warning: checkpoint-on-failure could not save {}: {save_err:#}",
                        path.display()
                    );
                }
                return Err(e);
            }
        }
    }
    // Cover the edge cases where the loop body never saved (an
    // already-complete resume, max_steps == 0) — but do not re-write a
    // final state that is already on disk: a spurious I/O error here
    // would turn a fully-completed, fully-checkpointed run into Err.
    if saved_at != Some(trainer.steps()) {
        let snap = trainer.checkpoint()?;
        save_snapshot(&path, &snap)?;
    }
    merged.steps_run = trainer.steps();
    merged.cost_evals = trainer.cost_evals();
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ScheduleKind;
    use crate::datasets::xor;
    use crate::device::NativeDevice;
    use crate::optim::init_params_uniform;
    use crate::rng::Rng;

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "mgd-ckpt-test-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn xor_device(seed: u64) -> NativeDevice {
        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        let mut rng = Rng::new(seed);
        let mut theta = vec![0f32; 9];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta).unwrap();
        dev
    }

    #[test]
    fn scalar_encodings_are_exact() {
        for v in [0u64, 1, 53, (1 << 53) + 1, u64::MAX] {
            assert_eq!(pu64(&ju64(v)).unwrap(), v, "u64 {v}");
        }
        for v in [0.0f32, -0.0, 1.5e-38, f32::NAN, f32::INFINITY, -3.25] {
            assert_eq!(pf32(&jf32(v)).unwrap().to_bits(), v.to_bits(), "f32 {v}");
        }
        for v in [0.0f64, -1.0e-300, std::f64::consts::PI, f64::NAN] {
            assert_eq!(pf64(&jf64(v)).unwrap().to_bits(), v.to_bits(), "f64 {v}");
        }
        assert!(pf32(&Json::Num(-1.0)).is_err());
        assert!(pf32(&Json::Num(0.5)).is_err());
        assert!(pf32(&Json::Num(u32::MAX as f64 + 1.0)).is_err());
        assert!(pu64(&Json::Num(3.0)).is_err(), "u64 must be a string");
        assert_eq!(popt_u64(&Json::Null).unwrap(), None);
        assert_eq!(popt_u64(&jopt_u64(Some(9))).unwrap(), Some(9));
    }

    #[test]
    fn snapshot_json_roundtrip_preserves_every_field() {
        let data = xor();
        let cfg = MgdConfig {
            tau_x: 3,
            tau_theta: 4,
            tau_p: 2,
            eta: 0.7,
            amplitude: 0.05,
            kind: PerturbKind::RademacherCode,
            noise: crate::noise::NoiseConfig { sigma_cost: 0.01, sigma_update: 0.002 },
            seed: 11,
        };
        let mut dev = xor_device(11);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..17 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        let doc = snap.to_json();
        // The document survives a serialize → parse → decode round trip
        // through the JSONL writer, bit for bit.
        let back = TrainerSnapshot::from_json(&Json::parse(&doc.dump()).unwrap()).unwrap();
        assert_eq!(back.n_params, snap.n_params);
        assert_eq!(back.step, snap.step);
        assert_eq!(back.cost_evals, snap.cost_evals);
        assert_eq!(back.c0.to_bits(), snap.c0.to_bits());
        assert_eq!(back.c0_valid, snap.c0_valid);
        assert_eq!(back.next_load_step, snap.next_load_step);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.theta), bits(&snap.theta));
        assert_eq!(bits(&back.g), bits(&snap.g));
        assert_eq!(bits(&back.xb), bits(&snap.xb));
        assert_eq!(bits(&back.yb), bits(&snap.yb));
        assert_eq!(back.rng, snap.rng);
        assert_eq!(back.schedule, snap.schedule);
        assert_eq!(back.pert, snap.pert);
        assert!(ensure_config_matches(&cfg, &back.config).is_ok());
        // v2 fields: the NativeDevice's spec identity rides along.
        assert_eq!(back.model.as_deref(), Some("2x2x1:sigmoid,sigmoid"));
        let spec: crate::model::ModelSpec = "2x2x1".parse().unwrap();
        assert_eq!(back.spec_hash, Some(spec.spec_hash()));
        // Scaling-engine fields: a forward-difference trainer with no
        // schedule writes the empty defaults.
        assert_eq!(back.pending_c.map(f32::to_bits), snap.pending_c.map(f32::to_bits));
        assert_eq!(snap.pending_c, None);
        assert!(back.layer_lr.is_empty() && back.layer_amp.is_empty());
    }

    #[test]
    fn scaling_engine_fields_roundtrip_and_default_when_absent() {
        let data = xor();
        let cfg = MgdConfig {
            tau_x: 2,
            tau_theta: 4,
            kind: PerturbKind::Antithetic,
            seed: 13,
            ..Default::default()
        };
        let mut dev = xor_device(13);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let sched = crate::perturb::PerLayerSchedule::new(vec![1.0, 0.5], vec![1.0, 0.25]).unwrap();
        tr.set_layer_schedule(&sched).unwrap();
        // Stop after an even step: the antithetic pair is half-open and
        // pending_c holds the even step's C⁺.
        for _ in 0..7 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        assert!(snap.pending_c.is_some(), "odd step count must leave a half-open pair");
        assert_eq!(snap.layer_lr, vec![1.0, 0.5]);
        assert_eq!(snap.layer_amp, vec![1.0, 0.25]);
        let back =
            TrainerSnapshot::from_json(&Json::parse(&snap.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.pending_c.map(f32::to_bits), snap.pending_c.map(f32::to_bits));
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.layer_lr), bits(&snap.layer_lr));
        assert_eq!(bits(&back.layer_amp), bits(&snap.layer_amp));
        // The config kind round-trips through its token form.
        assert_eq!(back.config.kind, PerturbKind::Antithetic);
        // A pre-engine v2 document omits all three keys; they read as
        // "nothing in flight, no schedule".
        let mut doc = match snap.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.remove("pending_c");
        doc.remove("layer_lr");
        doc.remove("layer_amp");
        let old = TrainerSnapshot::from_json(&Json::Obj(doc)).unwrap();
        assert_eq!(old.pending_c, None);
        assert!(old.layer_lr.is_empty() && old.layer_amp.is_empty());
    }

    #[test]
    fn block_sparse_kind_roundtrips_through_config_token() {
        let cfg = MgdConfig { kind: PerturbKind::BlockSparse { block: 3 }, ..Default::default() };
        let back = config_from_json(&config_to_json(&cfg)).unwrap();
        assert_eq!(back.kind, PerturbKind::BlockSparse { block: 3 });
        assert!(ensure_config_matches(&cfg, &back).is_ok());
        let live = MgdConfig { kind: PerturbKind::BlockSparse { block: 4 }, ..Default::default() };
        let err = ensure_config_matches(&live, &back).unwrap_err();
        assert!(format!("{err:#}").contains("block_sparse:3"), "{err:#}");
    }

    #[test]
    fn v1_checkpoints_load_under_the_compat_rule() {
        let data = xor();
        let cfg = MgdConfig { seed: 7, ..Default::default() };
        let mut dev = xor_device(7);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..4 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        // Rewrite the document as a v1 file: version 1, no spec fields —
        // exactly what a pre-v2 build produced.
        let mut doc = match snap.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.insert("version".to_string(), Json::Num(1.0));
        doc.remove("model");
        doc.remove("spec_hash");
        let v1 = TrainerSnapshot::from_json(&Json::Obj(doc.clone())).unwrap();
        assert_eq!(v1.model, None);
        assert_eq!(v1.spec_hash, None);
        assert_eq!(v1.step, snap.step);
        // The compat rule: a v1 snapshot restores with the spec gate
        // skipped (P is the only shape check), bit-identically.
        let mut dev2 = xor_device(7);
        let mut tr2 = MgdTrainer::new(&mut dev2, &data, cfg, ScheduleKind::Cyclic);
        tr2.restore(&v1).unwrap();
        assert_eq!(tr2.steps(), snap.step);
        // A v2 document must carry the spec fields (missing → error, so
        // a truncated v2 file cannot masquerade as spec-less).
        let mut bad = doc;
        bad.insert("version".to_string(), Json::Num(2.0));
        assert!(TrainerSnapshot::from_json(&Json::Obj(bad)).is_err());
    }

    #[test]
    fn restore_rejects_spec_mismatch_at_equal_param_count() {
        // 2x2x1 sigmoid and 2x2x1 relu,relu have identical P = 9: the
        // v1 parameter gate cannot tell them apart, the v2 spec gate
        // must.
        let data = xor();
        let cfg = MgdConfig { seed: 3, ..Default::default() };
        let mut dev = xor_device(3);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..2 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        let mut relu_dev =
            NativeDevice::from_spec("2x2x1:relu,relu".parse().unwrap(), 1).unwrap();
        relu_dev.set_params(&[0.1; 9]).unwrap();
        let mut tr2 = MgdTrainer::new(&mut relu_dev, &data, cfg, ScheduleKind::Cyclic);
        let err = tr2.restore(&snap).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("2x2x1:sigmoid,sigmoid"), "{msg}");
        assert!(msg.contains("2x2x1:relu,relu"), "{msg}");
    }

    #[test]
    fn prune_keeps_newest_rounds_and_heals_partial_gc() {
        let dir = temp_dir("prune");
        let touch = |r: u64, i: usize| {
            std::fs::write(dp_replica_path(&dir, i, r), "{}").unwrap();
        };
        for r in 1..=5u64 {
            for i in 0..2 {
                touch(r, i);
            }
        }
        // Unrelated files must never be collected.
        std::fs::write(dir.join("dp-meta.json"), "{}").unwrap();
        std::fs::write(dir.join("notes.txt"), "keep me").unwrap();
        save_dp_meta(&dir, 5, 2).unwrap();
        // keep=2 → rounds 4 and 5 stay, rounds 1..3 go (3 rounds × 2
        // replicas).
        assert_eq!(prune_dp_rounds(&dir, 5, 2).unwrap(), 6);
        for r in 1..=3u64 {
            for i in 0..2 {
                assert!(!dp_replica_path(&dir, i, r).exists(), "round {r} must be gone");
            }
        }
        for r in 4..=5u64 {
            for i in 0..2 {
                assert!(dp_replica_path(&dir, i, r).exists(), "round {r} must survive");
            }
        }
        assert!(dir.join("notes.txt").exists());
        assert_eq!(load_dp_meta(&dir).unwrap(), Some((5, 2)));
        // keep=0 is clamped to 1: the committed round always survives.
        assert_eq!(prune_dp_rounds(&dir, 5, 0).unwrap(), 2);
        assert!(dp_replica_path(&dir, 0, 5).exists());
        assert!(dp_replica_path(&dir, 1, 5).exists());
        // Kill-during-GC: recreate an old round and delete only half of
        // it — the partial state a crash mid-prune leaves behind.  The
        // next prune (next round's commit) heals the stragglers and the
        // committed round's snapshot set is intact throughout.
        touch(2, 0); // straggler: replica 0 of round 2 survived a torn GC
        assert_eq!(prune_dp_rounds(&dir, 5, 1).unwrap(), 1);
        assert!(!dp_replica_path(&dir, 0, 2).exists());
        assert!(dp_replica_path(&dir, 0, 5).exists());
        assert_eq!(load_dp_meta(&dir).unwrap(), Some((5, 2)));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn file_roundtrip_and_version_gate() {
        let dir = temp_dir("file");
        let data = xor();
        let cfg = MgdConfig { seed: 5, ..Default::default() };
        let mut dev = xor_device(5);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..5 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        let path = checkpoint_path(&dir);
        save_snapshot(&path, &snap).unwrap();
        let back = load_snapshot(&path).unwrap();
        assert_eq!(back.step, 5);
        // A future version is rejected, not misread.
        let mut doc = match snap.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        doc.insert("version".to_string(), Json::Num(99.0));
        write_json_atomic(&path, &Json::Obj(doc)).unwrap();
        let err = load_snapshot(&path).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"), "{err:#}");
        // Garbage is a parse error, not a panic.
        std::fs::write(&path, "{not json").unwrap();
        assert!(load_snapshot(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_config_and_shape_mismatches() {
        let data = xor();
        let cfg = MgdConfig { seed: 2, ..Default::default() };
        let mut dev = xor_device(2);
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..3 {
            tr.step().unwrap();
        }
        let snap = tr.checkpoint().unwrap();
        // Different eta → named mismatch.
        let mut dev2 = xor_device(2);
        let cfg2 = MgdConfig { eta: 2.5, seed: 2, ..Default::default() };
        let mut tr2 = MgdTrainer::new(&mut dev2, &data, cfg2, ScheduleKind::Cyclic);
        let err = tr2.restore(&snap).unwrap_err();
        assert!(format!("{err:#}").contains("eta"), "{err:#}");
        // Different model shape → parameter-count error.
        let mut dev3 = NativeDevice::new(&[4, 4, 1], 1);
        dev3.set_params(&[0.1; 25]).unwrap();
        let par = crate::datasets::parity(4);
        let mut tr3 = MgdTrainer::new(&mut dev3, &par, cfg, ScheduleKind::Cyclic);
        let err = tr3.restore(&snap).unwrap_err();
        assert!(format!("{err:#}").contains("parameter"), "{err:#}");
    }

    #[test]
    fn train_checkpointed_chunks_match_one_shot_training() {
        let data = xor();
        let cfg = MgdConfig {
            tau_x: 2,
            tau_theta: 4,
            eta: 0.8,
            amplitude: 0.05,
            seed: 31,
            ..Default::default()
        };
        let opts = TrainOptions { max_steps: 120, eval_every: 40, ..Default::default() };
        // One-shot reference.
        let mut dev_a = xor_device(31);
        let mut tr_a = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
        let res_a = tr_a.train_batched(&opts, None, 3).unwrap();
        let theta_a = tr_a.device_params().unwrap();
        // Checkpointed in 7-step chunks (boundaries land mid-window, mid
        // τθ — everywhere).
        let dir = temp_dir("chunks");
        let ck = CheckpointConfig { dir: dir.clone(), every_steps: 7, resume: false };
        let mut dev_b = xor_device(31);
        let mut tr_b = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
        let res_b = train_checkpointed(&mut tr_b, &opts, None, 3, &ck).unwrap();
        assert_eq!(res_a.steps_run, res_b.steps_run);
        assert_eq!(res_a.cost_evals, res_b.cost_evals);
        assert_eq!(res_a.eval_trace.len(), res_b.eval_trace.len());
        let theta_b = tr_b.device_params().unwrap();
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&theta_a), bits(&theta_b));
        // The on-disk checkpoint holds the final state.
        let snap = load_snapshot(&checkpoint_path(&dir)).unwrap();
        assert_eq!(snap.step, 120);
        assert_eq!(bits(&snap.theta), bits(&theta_a));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dp_meta_roundtrip() {
        let dir = temp_dir("dpmeta");
        assert_eq!(load_dp_meta(&dir).unwrap(), None);
        save_dp_meta(&dir, 3, 4).unwrap();
        assert_eq!(load_dp_meta(&dir).unwrap(), Some((3, 4)));
        std::fs::remove_dir_all(&dir).ok();
    }
}
