//! Algorithm 1 — the discrete MGD training loop, step by step.
//!
//! This is the reference implementation of the paper's training semantics
//! and the chip-in-the-loop driver: every timestep costs exactly one
//! perturbed device inference, plus a baseline (C₀) re-measurement
//! whenever the sample window or the parameters changed (Algorithm 1
//! lines 5–7).  All four perturbation families plug in unchanged.
//!
//! The trainer exposes a fine-grained [`MgdTrainer::step`] (used by the
//! Fig. 2/3 trace harnesses and the Fig. 5 infinite-integration mode) and
//! a batch [`MgdTrainer::train`] loop with the stopping criteria the
//! paper's experiments use.

use anyhow::Result;

use super::schedule::{SampleSchedule, ScheduleKind};
use super::{MgdConfig, TrainOptions, TrainResult};
use crate::datasets::Dataset;
use crate::device::HardwareDevice;
use crate::perturb::{self, Perturbation};
use crate::rng::Rng;

/// What one timestep observed (for trace harnesses).
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    /// Global step index (starts at 0).
    pub step: u64,
    /// Perturbed cost C measured this step (noise included).
    pub cost: f32,
    /// Cost modulation C̃ = C − C₀ used for the homodyne product.
    pub c_tilde: f32,
    /// Whether a parameter update fired at the end of this step.
    pub updated: bool,
}

/// The discrete MGD trainer (Algorithm 1) over a black-box device.
pub struct MgdTrainer<'d> {
    dev: &'d mut dyn HardwareDevice,
    cfg: MgdConfig,
    pert: Box<dyn Perturbation>,
    schedule: SampleSchedule,
    dataset: &'d Dataset,
    /// Gradient integrator G (Eq. 3, accumulated — not 1/T-normalized;
    /// see the paper's footnote 1).
    g: Vec<f32>,
    /// Scratch perturbation vector.
    tt: Vec<f32>,
    /// Scratch update vector (−ηG + noise).
    delta: Vec<f32>,
    /// Reusable batch buffers (hot loop, no per-step allocation).
    xb: Vec<f32>,
    yb: Vec<f32>,
    /// Cached baseline cost C₀ and its validity.
    c0: f32,
    c0_valid: bool,
    step: u64,
    rng: Rng,
    cost_evals: u64,
}

impl<'d> MgdTrainer<'d> {
    /// Build a trainer.  The device's parameters must already be
    /// initialized (see [`crate::optim::init_params`]).
    pub fn new(
        dev: &'d mut dyn HardwareDevice,
        dataset: &'d Dataset,
        cfg: MgdConfig,
        schedule_kind: ScheduleKind,
    ) -> Self {
        let p = dev.n_params();
        let batch = dev.batch_size();
        let schedule = SampleSchedule::new(dataset, batch, schedule_kind, cfg.seed);
        let pert = perturb::make(cfg.kind, p, cfg.amplitude, cfg.tau_p, cfg.seed);
        MgdTrainer {
            dev,
            cfg,
            pert,
            schedule,
            dataset,
            g: vec![0.0; p],
            tt: vec![0.0; p],
            delta: vec![0.0; p],
            xb: Vec::new(),
            yb: Vec::new(),
            c0: 0.0,
            c0_valid: false,
            step: 0,
            rng: Rng::new(cfg.seed ^ 0x4d47_4431), // "MGD1"
            cost_evals: 0,
        }
    }

    /// Current gradient integrator G (Fig. 5 reads this with τθ = ∞).
    pub fn gradient(&self) -> &[f32] {
        &self.g
    }

    /// Steps executed so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Device cost-evaluations so far (perturbed + baseline).
    pub fn cost_evals(&self) -> u64 {
        self.cost_evals
    }

    /// The configuration in force.
    pub fn config(&self) -> &MgdConfig {
        &self.cfg
    }

    /// Snapshot the device's parameter memory (off-hot-path; trace
    /// harnesses use this for the Fig. 2/3 θ traces).
    pub fn device_params(&mut self) -> Result<Vec<f32>> {
        self.dev.get_params()
    }

    /// Overwrite the device's parameter memory mid-training — the fleet's
    /// data-parallel averaging entry point.  Clears the gradient
    /// integrator G and invalidates the cached baseline cost C₀ (both are
    /// functions of the old θ).
    pub fn sync_params(&mut self, theta: &[f32]) -> Result<()> {
        self.dev.set_params(theta)?;
        self.g.fill(0.0);
        self.c0_valid = false;
        Ok(())
    }

    /// Evaluate the device on a labelled set (the accuracy probe, exposed
    /// so fleet drivers can measure synchronized parameters without
    /// reaching around the trainer).  Returns `(cost, #correct)`.
    pub fn evaluate_on(&mut self, set: &Dataset) -> Result<(f32, f32)> {
        self.dev.evaluate(&set.x, &set.y, set.n)
    }

    /// Execute one MGD timestep (Algorithm 1 loop body).
    pub fn step(&mut self) -> Result<StepOutput> {
        let n = self.step;

        // Lines 3–4: new training sample window every τx.
        if n % self.cfg.tau_x.max(1) == 0 {
            let idx = self.schedule.next_window();
            self.dataset.gather_into(&idx, &mut self.xb, &mut self.yb);
            self.dev.load_batch(&self.xb, &self.yb)?;
            self.c0_valid = false;
        }

        // Lines 5–7: re-measure the baseline cost C₀ (θ̃ = 0) when the
        // sample window or the parameters changed.
        if !self.c0_valid {
            self.c0 = self.dev.cost(None)? + self.cfg.noise.cost_noise(&mut self.rng);
            self.cost_evals += 1;
            self.c0_valid = true;
        }

        // Lines 8–9: advance the perturbation pattern every τp (the
        // generator itself holds the pattern within a τp window).
        self.pert.fill(n, &mut self.tt);

        // Lines 10–12: perturbed inference, cost, modulation.
        let c = self.dev.cost(Some(&self.tt))? + self.cfg.noise.cost_noise(&mut self.rng);
        self.cost_evals += 1;
        let c_tilde = c - self.c0;

        // Lines 13–14: homodyne error signal, accumulated into G.
        let inv_a2 = 1.0 / (self.cfg.amplitude * self.cfg.amplitude);
        for (g, &t) in self.g.iter_mut().zip(self.tt.iter()) {
            *g += c_tilde * t * inv_a2;
        }

        // Lines 15–17: parameter update every τθ.
        let updated = self.cfg.tau_theta != u64::MAX
            && (n + 1) % self.cfg.tau_theta.max(1) == 0;
        if updated {
            for (d, &g) in self.delta.iter_mut().zip(self.g.iter()) {
                *d = -self.cfg.eta * g;
            }
            // §3.5 test 2: stochastic parameter-update noise (Eq. 5).
            self.cfg.noise.apply_update_noise(&mut self.rng, &mut self.delta);
            self.dev.apply_update(&self.delta)?;
            self.g.fill(0.0);
            self.c0_valid = false;
        }

        self.step += 1;
        Ok(StepOutput { step: n, cost: c, c_tilde, updated })
    }

    /// Run the training loop with the given stopping/recording options.
    /// `eval_set` provides the accuracy probe (defaults to the training
    /// set for the paper's small problems).
    pub fn train(&mut self, opts: &TrainOptions, eval_set: Option<&Dataset>) -> Result<TrainResult> {
        let eval = eval_set.unwrap_or(self.dataset);
        let mut result = TrainResult::default();
        while self.step < opts.max_steps {
            let out = self.step()?;
            if opts.record_cost_every > 0 && out.step % opts.record_cost_every == 0 {
                result.cost_trace.push((out.step, out.cost));
            }
            let check = opts.eval_every > 0 && (out.step + 1) % opts.eval_every == 0;
            if check {
                let (cost, correct) = self.dev.evaluate(&eval.x, &eval.y, eval.n)?;
                let acc = correct / eval.n as f32;
                result.eval_trace.push((out.step, cost, acc));
                let cost_hit = opts.target_cost.is_some_and(|t| cost < t);
                let acc_hit = opts.target_accuracy.is_some_and(|t| acc >= t);
                if cost_hit || acc_hit {
                    result.solved_at = Some(out.step);
                    break;
                }
            }
        }
        result.steps_run = self.step;
        result.cost_evals = self.cost_evals;
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::xor;
    use crate::device::NativeDevice;
    use crate::optim::init_params_uniform;
    use crate::perturb::PerturbKind;

    fn xor_device(seed: u64) -> NativeDevice {
        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        let mut rng = Rng::new(seed);
        let mut theta = vec![0f32; 9];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta).unwrap();
        dev
    }

    #[test]
    fn solves_xor_with_spsa_settings() {
        // Paper Table 2 row 1: XOR with τθ = τp = 1 and η ≈ 5 solves
        // reliably within 10⁴ steps.  Use a couple of seeds; at least one
        // must solve quickly and none may blow up.
        let data = xor();
        let mut solved_any = false;
        for seed in 0..3u64 {
            let mut dev = xor_device(seed);
            let cfg = MgdConfig {
                eta: 2.0,
                amplitude: 0.05,
                kind: PerturbKind::RademacherCode,
                seed,
                ..Default::default()
            };
            let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            let opts = TrainOptions {
                max_steps: 60_000,
                eval_every: 500,
                target_cost: Some(0.04),
                ..Default::default()
            };
            let res = tr.train(&opts, None).unwrap();
            assert!(res.steps_run > 0);
            if res.solved() {
                solved_any = true;
            }
        }
        assert!(solved_any, "no seed solved XOR within the budget");
    }

    #[test]
    fn infinite_tau_theta_never_updates() {
        let data = xor();
        let mut dev = xor_device(1);
        let theta_before = dev.get_params().unwrap();
        let cfg = MgdConfig { tau_theta: u64::MAX, seed: 1, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..100 {
            let out = tr.step().unwrap();
            assert!(!out.updated);
        }
        assert!(tr.gradient().iter().any(|&g| g != 0.0), "G never accumulated");
        assert_eq!(dev.get_params().unwrap(), theta_before);
    }

    #[test]
    fn gradient_estimate_correlates_with_true_gradient() {
        // Homodyne G (τθ=∞) must point in the same half-space as the true
        // gradient after enough integration — the core Eq. 3 property.
        let data = xor();
        let mut dev = xor_device(3);
        let theta = dev.get_params().unwrap();
        let cfg = MgdConfig {
            tau_theta: u64::MAX,
            amplitude: 0.01,
            seed: 3,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..4000 {
            tr.step().unwrap();
        }
        let g = tr.gradient().to_vec();
        // Finite-difference true gradient of the mean dataset cost.
        let mut true_g = vec![0f32; 9];
        let eps = 1e-3f32;
        let mut dev2 = NativeDevice::new(&[2, 2, 1], 4);
        dev2.set_params(&theta).unwrap();
        dev2.load_batch(&data.x, &data.y).unwrap();
        let base = dev2.cost(None).unwrap();
        for i in 0..9 {
            let mut tt = vec![0f32; 9];
            tt[i] = eps;
            true_g[i] = (dev2.cost(Some(&tt)).unwrap() - base) / eps;
        }
        let dot: f32 = g.iter().zip(&true_g).map(|(a, b)| a * b).sum();
        let na: f32 = g.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = true_g.iter().map(|v| v * v).sum::<f32>().sqrt();
        let cos = dot / (na * nb);
        assert!(cos > 0.5, "G misaligned with true gradient: cos = {cos}");
    }

    #[test]
    fn tau_theta_controls_update_cadence() {
        let data = xor();
        let mut dev = xor_device(2);
        let cfg = MgdConfig { tau_theta: 5, seed: 2, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let mut updates = Vec::new();
        for _ in 0..20 {
            let out = tr.step().unwrap();
            if out.updated {
                updates.push(out.step);
            }
        }
        assert_eq!(updates, vec![4, 9, 14, 19]);
    }

    #[test]
    fn sync_params_overwrites_and_resets_state() {
        let data = xor();
        let mut dev = xor_device(6);
        let cfg = MgdConfig { tau_theta: u64::MAX, seed: 6, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..10 {
            tr.step().unwrap();
        }
        assert!(tr.gradient().iter().any(|&g| g != 0.0));
        tr.sync_params(&[0.25; 9]).unwrap();
        assert!(tr.gradient().iter().all(|&g| g == 0.0), "G must reset on sync");
        assert_eq!(tr.device_params().unwrap(), vec![0.25; 9]);
        let (cost, correct) = tr.evaluate_on(&data).unwrap();
        assert!(cost.is_finite() && correct <= data.n as f32);
        // Training continues cleanly after the sync.
        tr.step().unwrap();
    }

    #[test]
    fn cost_evals_track_baseline_caching() {
        let data = xor();
        let mut dev = xor_device(4);
        // τx = 10, τθ = MAX: baseline measured once per sample window.
        let cfg = MgdConfig {
            tau_x: 10,
            tau_theta: u64::MAX,
            seed: 4,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..20 {
            tr.step().unwrap();
        }
        // 20 perturbed + 2 baselines (steps 0 and 10).
        assert_eq!(tr.cost_evals(), 22);
    }
}
