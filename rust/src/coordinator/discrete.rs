//! Algorithm 1 — the discrete MGD training loop, step by step.
//!
//! This is the reference implementation of the paper's training semantics
//! and the chip-in-the-loop driver: every timestep costs exactly one
//! perturbed device inference, plus a baseline (C₀) re-measurement
//! whenever the sample window or the parameters changed (Algorithm 1
//! lines 5–7).  All four perturbation families plug in unchanged.
//!
//! The trainer exposes a fine-grained [`MgdTrainer::step`] (used by the
//! Fig. 2/3 trace harnesses and the Fig. 5 infinite-integration mode) and
//! a batch [`MgdTrainer::train`] loop with the stopping criteria the
//! paper's experiments use.
//!
//! For I/O-limited devices (chip-in-the-loop over TCP, §6) the same
//! semantics are available K timesteps at a time: [`MgdTrainer::step_window`]
//! stacks a whole parameter-hold window of probes into one
//! [`HardwareDevice::cost_many`] call, bit-identically to the serial loop,
//! and [`MgdTrainer::train_batched`] is the corresponding training driver.

use std::sync::OnceLock;

use anyhow::{bail, Result};

use super::checkpoint::{ensure_config_matches, TrainerSnapshot};
use super::schedule::{SampleSchedule, ScheduleKind};
use super::{MgdConfig, TrainOptions, TrainResult};
use crate::datasets::Dataset;
use crate::device::HardwareDevice;
use crate::obs;
use crate::perturb::{self, PerLayerSchedule, PerturbKind, Perturbation};
use crate::rng::Rng;

/// What one timestep observed (for trace harnesses).
#[derive(Debug, Clone, Copy)]
pub struct StepOutput {
    /// Global step index (starts at 0).
    pub step: u64,
    /// Perturbed cost C measured this step (noise included).
    pub cost: f32,
    /// Cost modulation used for the homodyne product: `C − C₀` for the
    /// forward-difference families; for antithetic pairs, `0.0` on the
    /// even (`+θ̃`) step and the central difference `(C⁻ − C⁺)/2` on the
    /// odd (`−θ̃`) step that closes the pair.
    pub c_tilde: f32,
    /// Whether a parameter update fired at the end of this step.
    pub updated: bool,
}

/// Cached handles to the trainer's registered [`obs`] series (resolved
/// once; every update afterwards is a relaxed atomic).
struct TrainerMetrics {
    steps: obs::Counter,
    cost_evals: obs::Counter,
    cost: obs::Gauge,
    eval_cost: obs::Gauge,
    eval_accuracy: obs::Gauge,
    g_norm: obs::Gauge,
    probe_window: obs::Gauge,
}

fn trainer_metrics() -> &'static TrainerMetrics {
    static M: OnceLock<TrainerMetrics> = OnceLock::new();
    M.get_or_init(|| TrainerMetrics {
        steps: obs::counter("mgd_trainer_steps_total"),
        cost_evals: obs::counter("mgd_trainer_cost_evals_total"),
        cost: obs::gauge("mgd_trainer_cost"),
        eval_cost: obs::gauge("mgd_trainer_eval_cost"),
        eval_accuracy: obs::gauge("mgd_trainer_eval_accuracy"),
        g_norm: obs::gauge("mgd_trainer_g_norm"),
        probe_window: obs::gauge("mgd_trainer_probe_window"),
    })
}

/// Publish ‖G‖₂ — computed in f64 over a read-only view of the f32
/// integrator, so the training arithmetic itself stays bit-identical.
fn record_g_norm(g: &[f32]) {
    if obs::enabled() {
        let sq: f64 = g.iter().map(|&v| v as f64 * v as f64).sum();
        trainer_metrics().g_norm.set(sq.sqrt());
    }
}

/// Per-parameter expansion of a [`PerLayerSchedule`] — the hot-path
/// form, tiled over `param_layout()` once at configuration time.
struct LayerScales {
    /// η multiplier per parameter.
    lr: Vec<f32>,
    /// Probe-amplitude multiplier per parameter.
    amp: Vec<f32>,
    /// `1/Δθ_i²` per parameter, with `Δθ_i = Δθ · amp_i`.
    inv_a2: Vec<f32>,
}

/// The discrete MGD trainer (Algorithm 1) over a black-box device.
pub struct MgdTrainer<'d> {
    dev: &'d mut dyn HardwareDevice,
    cfg: MgdConfig,
    pert: Box<dyn Perturbation>,
    schedule: SampleSchedule,
    dataset: &'d Dataset,
    /// Gradient integrator G (Eq. 3, accumulated — not 1/T-normalized;
    /// see the paper's footnote 1).
    g: Vec<f32>,
    /// Scratch perturbation vector.
    tt: Vec<f32>,
    /// Scratch probe stack for [`MgdTrainer::step_window`] (K·P floats,
    /// grown on demand — no per-window allocation).
    probes: Vec<f32>,
    /// Scratch update vector (−ηG + noise).
    delta: Vec<f32>,
    /// Reusable batch buffers (hot loop, no per-step allocation).
    xb: Vec<f32>,
    yb: Vec<f32>,
    /// Cached baseline cost C₀ and its validity.
    c0: f32,
    c0_valid: bool,
    /// First step at or after which a new sample window must be loaded.
    /// Equivalent to the `n % τx == 0` check for a sequential run, but
    /// crash-consistent: once the schedule has been consumed for step n,
    /// this advances, so a checkpoint taken after a mid-step failure
    /// never re-consumes the schedule on resume (which would silently
    /// train a different trajectory).
    next_load_step: u64,
    step: u64,
    rng: Rng,
    cost_evals: u64,
    /// Antithetic pairing: the even step's measured `C⁺`, waiting for the
    /// odd step's `C⁻` to close the central difference.  `None` outside a
    /// half-open pair.  Forward-difference families never set it.
    pending_c: Option<f32>,
    /// Per-parameter schedule expansions (`None` = scalar fast path,
    /// bit-identical to the pre-schedule trainer).
    scales: Option<LayerScales>,
    /// The per-layer schedule as configured (checkpoint identity).
    layer_schedule: Option<PerLayerSchedule>,
}

impl<'d> MgdTrainer<'d> {
    /// Build a trainer, validating the configuration against the device.
    /// The device's parameters must already be initialized (see
    /// [`crate::optim::init_params`]).
    ///
    /// Fails when [`PerturbKind::LayerSparse`] is requested on a device
    /// with no [`ModelSpec`](crate::model::ModelSpec), or when
    /// [`PerturbKind::Antithetic`] is paired with an odd `τx`/`τθ`
    /// cadence (a ±pair must never straddle a sample change or a
    /// parameter update — the two evals would measure different cost
    /// surfaces).
    pub fn try_new(
        dev: &'d mut dyn HardwareDevice,
        dataset: &'d Dataset,
        cfg: MgdConfig,
        schedule_kind: ScheduleKind,
    ) -> Result<Self> {
        if cfg.kind == PerturbKind::Antithetic {
            let tau_x = cfg.tau_x.max(1);
            if tau_x % 2 != 0 {
                bail!("antithetic probes pair consecutive steps: τx must be even (got {tau_x})");
            }
            let tau_t = cfg.tau_theta;
            if tau_t != u64::MAX && tau_t.max(1) % 2 != 0 {
                bail!("antithetic pairing needs τθ even or ∞ (got {tau_t})");
            }
        }
        let p = dev.n_params();
        let batch = dev.batch_size();
        let layout = dev.model_spec().map(|s| s.param_layout());
        let pert = perturb::make_with_layout(
            cfg.kind,
            p,
            cfg.amplitude,
            cfg.tau_p,
            cfg.seed,
            layout.as_deref(),
        )?;
        let schedule = SampleSchedule::new(dataset, batch, schedule_kind, cfg.seed);
        Ok(MgdTrainer {
            dev,
            cfg,
            pert,
            schedule,
            dataset,
            g: vec![0.0; p],
            tt: vec![0.0; p],
            probes: Vec::new(),
            delta: vec![0.0; p],
            xb: Vec::new(),
            yb: Vec::new(),
            c0: 0.0,
            c0_valid: false,
            next_load_step: 0,
            step: 0,
            rng: Rng::new(cfg.seed ^ 0x4d47_4431), // "MGD1"
            cost_evals: 0,
            pending_c: None,
            scales: None,
            layer_schedule: None,
        })
    }

    /// [`MgdTrainer::try_new`] for configurations that cannot fail (the
    /// four dense families on any device; every family on a
    /// spec-carrying device with a valid cadence).
    ///
    /// # Panics
    ///
    /// When `try_new` would return an error.
    pub fn new(
        dev: &'d mut dyn HardwareDevice,
        dataset: &'d Dataset,
        cfg: MgdConfig,
        schedule_kind: ScheduleKind,
    ) -> Self {
        Self::try_new(dev, dataset, cfg, schedule_kind)
            .expect("MgdTrainer construction failed; use try_new for fallible configurations")
    }

    /// Install a per-layer learning-rate/amplitude schedule
    /// ([`PerLayerSchedule`]), expanded over the device spec's
    /// `param_layout()`.  Must be called before any steps run (the
    /// expansion scales probes and updates from step 0; installing it
    /// mid-run would silently change the estimator).  An all-`1.0`
    /// schedule trains bit-identically to no schedule.
    pub fn set_layer_schedule(&mut self, sched: &PerLayerSchedule) -> Result<()> {
        if self.step != 0 {
            bail!("per-layer schedule must be installed before training starts");
        }
        let Some(spec) = self.dev.model_spec() else {
            bail!("per-layer schedules need a device that exposes a ModelSpec");
        };
        let p = self.g.len();
        let (lr, amp) = sched.expand(&spec.param_layout(), p)?;
        let inv_a2: Vec<f32> = amp
            .iter()
            .map(|&a| {
                let da = self.cfg.amplitude * a;
                1.0 / (da * da)
            })
            .collect();
        self.scales = Some(LayerScales { lr, amp, inv_a2 });
        self.layer_schedule = Some(sched.clone());
        Ok(())
    }

    /// The per-layer schedule in force, if any.
    pub fn layer_schedule(&self) -> Option<&PerLayerSchedule> {
        self.layer_schedule.as_ref()
    }

    /// Current gradient integrator G (Fig. 5 reads this with τθ = ∞).
    pub fn gradient(&self) -> &[f32] {
        &self.g
    }

    /// Steps executed so far.
    pub fn steps(&self) -> u64 {
        self.step
    }

    /// Device cost-evaluations so far (perturbed + baseline).
    pub fn cost_evals(&self) -> u64 {
        self.cost_evals
    }

    /// The configuration in force.
    pub fn config(&self) -> &MgdConfig {
        &self.cfg
    }

    /// Snapshot the device's parameter memory (off-hot-path; trace
    /// harnesses use this for the Fig. 2/3 θ traces).
    pub fn device_params(&mut self) -> Result<Vec<f32>> {
        self.dev.get_params()
    }

    /// Overwrite the device's parameter memory mid-training — the fleet's
    /// data-parallel averaging entry point.  Clears the gradient
    /// integrator G, invalidates the cached baseline cost C₀, and drops
    /// any half-open antithetic pair (all are functions of the old θ; an
    /// orphaned odd step then accumulates nothing, deterministically).
    pub fn sync_params(&mut self, theta: &[f32]) -> Result<()> {
        self.dev.set_params(theta)?;
        self.g.fill(0.0);
        self.c0_valid = false;
        self.pending_c = None;
        Ok(())
    }

    /// Evaluate the device on a labelled set (the accuracy probe, exposed
    /// so fleet drivers can measure synchronized parameters without
    /// reaching around the trainer).  Returns `(cost, #correct)`.
    ///
    /// This is the **single** eval entry point of the trainer — the
    /// in-loop accuracy checks of [`MgdTrainer::train_batched`] route
    /// through it too, one batched `evaluate` device call per probe (no
    /// per-sample loop anywhere).  For spec-carrying local devices the
    /// call lands on the shared batched forward executor
    /// ([`crate::device::exec`]) and its [`crate::device::exec::score_batch`]
    /// head — the same kernels and the same prediction rule the serving
    /// path ([`crate::serve::InferenceEngine`]) runs, so a train-time
    /// accuracy and the served accuracy of the same checkpoint are
    /// bit-comparable (pinned in `rust/tests/integration_serve.rs`).
    pub fn evaluate_on(&mut self, set: &Dataset) -> Result<(f32, f32)> {
        let (cost, correct) = self.dev.evaluate(&set.x, &set.y, set.n)?;
        let m = trainer_metrics();
        m.eval_cost.set(cost as f64);
        m.eval_accuracy.set(correct as f64 / set.n.max(1) as f64);
        Ok((cost, correct))
    }

    /// Capture the complete training state as a serializable snapshot —
    /// θ (read back from the device), the gradient integrator G, the
    /// cached baseline C₀, the loaded sample window, step/cost-eval
    /// counters, and the *full* internal state of the noise RNG, the
    /// sample schedule and the perturbation generator.
    ///
    /// Restoring the snapshot into a freshly built trainer
    /// ([`MgdTrainer::restore`]) continues the run **bit-identically**:
    /// the same θ/G trajectory, the same noise-draw order, the same
    /// `cost_evals` count as if training had never stopped — the same
    /// contract [`MgdTrainer::step_window`] keeps for batching.  Device
    /// *internals* (e.g. a [`crate::noise::NeuronDefects`] table) are
    /// not captured: the caller owns rebuilding the device identically,
    /// exactly as it owned building it in the first place.
    pub fn checkpoint(&mut self) -> Result<TrainerSnapshot> {
        let spec = self.dev.model_spec();
        Ok(TrainerSnapshot {
            config: self.cfg,
            n_params: self.g.len(),
            model: spec.as_ref().map(|s| s.to_string()),
            spec_hash: spec.as_ref().map(|s| s.spec_hash()),
            theta: self.dev.get_params()?,
            g: self.g.clone(),
            xb: self.xb.clone(),
            yb: self.yb.clone(),
            c0: self.c0,
            c0_valid: self.c0_valid,
            next_load_step: self.next_load_step,
            step: self.step,
            cost_evals: self.cost_evals,
            rng: self.rng.state(),
            schedule: self.schedule.export_state(),
            pert: self.pert.export_state(),
            pending_c: self.pending_c,
            layer_lr: self.layer_schedule.as_ref().map(|s| s.lr().to_vec()).unwrap_or_default(),
            layer_amp: self.layer_schedule.as_ref().map(|s| s.amp().to_vec()).unwrap_or_default(),
        })
    }

    /// Restore a snapshot taken by [`MgdTrainer::checkpoint`] into this
    /// trainer.  The trainer must have been built with the *same*
    /// configuration, dataset shape and device shape; mismatches are
    /// rejected rather than silently diverging.
    pub fn restore(&mut self, snap: &TrainerSnapshot) -> Result<()> {
        ensure_config_matches(&self.cfg, &snap.config)?;
        // The per-layer schedule is part of the training configuration:
        // resuming under a different one would silently change the
        // estimator.  Compared bit-exactly, like every other config field.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        let saved_none = snap.layer_lr.is_empty() && snap.layer_amp.is_empty();
        match (&self.layer_schedule, saved_none) {
            (None, true) => {}
            (Some(live), false) => {
                if bits(live.lr()) != bits(&snap.layer_lr)
                    || bits(live.amp()) != bits(&snap.layer_amp)
                {
                    bail!(
                        "checkpoint was taken under a different per-layer schedule — \
                         pass the same --layer-lr/--layer-amp values to resume"
                    );
                }
            }
            (None, false) => bail!(
                "checkpoint carries a per-layer schedule but the trainer has none — \
                 pass the same --layer-lr/--layer-amp values to resume"
            ),
            (Some(_), true) => bail!(
                "trainer has a per-layer schedule but the checkpoint was taken without one"
            ),
        }
        // Spec identity gate (checkpoint format v2): a snapshot taken on
        // one model must not restore into a different one, even when
        // their parameter counts collide.  v1 snapshots and spec-less
        // devices carry no identity — they stay on the P-only check (the
        // documented compat rule).
        if let (Some(saved), Some(live)) = (snap.spec_hash, self.dev.model_spec()) {
            if saved != live.spec_hash() {
                bail!(
                    "checkpoint was taken on model {} but the trainer's device runs {live}",
                    snap.model.as_deref().unwrap_or("<unknown>"),
                );
            }
        }
        let p = self.g.len();
        if snap.n_params != p || snap.theta.len() != p || snap.g.len() != p {
            bail!(
                "checkpoint is for a {}-parameter model (θ {}, G {}), trainer has {p}",
                snap.n_params,
                snap.theta.len(),
                snap.g.len()
            );
        }
        if snap.xb.is_empty() != snap.yb.is_empty() {
            bail!("corrupt checkpoint: sample window x/y presence disagrees");
        }
        self.dev.set_params(&snap.theta)?;
        self.xb.clear();
        self.xb.extend_from_slice(&snap.xb);
        self.yb.clear();
        self.yb.extend_from_slice(&snap.yb);
        // The loaded sample window is device-side state: replay it so a
        // snapshot taken mid-τx-window resumes against the same samples.
        if !self.xb.is_empty() {
            self.dev.load_batch(&self.xb, &self.yb)?;
        }
        self.g.copy_from_slice(&snap.g);
        self.c0 = snap.c0;
        self.c0_valid = snap.c0_valid;
        self.next_load_step = snap.next_load_step;
        self.step = snap.step;
        self.cost_evals = snap.cost_evals;
        self.rng.set_state(snap.rng);
        self.schedule.import_state(&snap.schedule)?;
        self.pert.import_state(&snap.pert)?;
        self.pending_c = snap.pending_c;
        Ok(())
    }

    /// Lines 3–4 of Algorithm 1: consume the schedule and load a new
    /// sample window when one is due at step `n`.  Crash-consistent: the
    /// schedule advance and the `next_load_step` watermark commit
    /// *before* the fallible device call, and `xb`/`yb` hold the new
    /// window, so a checkpoint taken after a failure here resumes by
    /// replaying `load_batch` from `xb` instead of re-consuming the
    /// schedule.
    fn load_window_if_due(&mut self, n: u64) -> Result<()> {
        if n < self.next_load_step {
            return Ok(());
        }
        let idx = self.schedule.next_window();
        self.dataset.gather_into(&idx, &mut self.xb, &mut self.yb);
        self.next_load_step = n + self.cfg.tau_x.max(1);
        self.c0_valid = false;
        self.dev.load_batch(&self.xb, &self.yb)?;
        Ok(())
    }

    /// Scale a freshly filled probe slice by the per-parameter amplitude
    /// multipliers, when a per-layer schedule is installed.
    fn scale_probe(scales: &Option<LayerScales>, tt: &mut [f32]) {
        if let Some(s) = scales {
            for (t, &a) in tt.iter_mut().zip(&s.amp) {
                *t *= a;
            }
        }
    }

    /// The pairing rule shared by [`MgdTrainer::step`] and the
    /// [`MgdTrainer::step_window`] replay: turn this step's measured cost
    /// into `(c_tilde, accumulate)`.
    ///
    /// Forward-difference families modulate against the cached baseline
    /// and always accumulate.  Antithetic pairs instead combine across
    /// timesteps: the even step parks `C⁺` and accumulates nothing (an
    /// explicit skip — accumulating a `0.0·θ̃` term could still flip G
    /// sign bits through `−0.0`); the odd step closes the pair with the
    /// central difference `(C⁻ − C⁺)/2`, which — applied to its own
    /// negated probe — is algebraically `(C⁺ − C⁻)/2 · θ̃⁺`.  An odd step
    /// with no parked `C⁺` (first step after a restore from a pre-pair
    /// snapshot, or after [`MgdTrainer::sync_params`] dropped the pair)
    /// accumulates nothing, deterministically on every path.
    fn pair_cost(&mut self, n: u64, c: f32) -> (f32, bool) {
        if self.cfg.kind == PerturbKind::Antithetic {
            if n % 2 == 0 {
                self.pending_c = Some(c);
                (0.0, false)
            } else {
                match self.pending_c.take() {
                    Some(c_plus) => ((c - c_plus) * 0.5, true),
                    None => (0.0, false),
                }
            }
        } else {
            (c - self.c0, true)
        }
    }

    /// Lines 13–14: the homodyne product, accumulated into G — scalar
    /// `1/Δθ²` fast path, or per-parameter when a schedule is installed.
    /// (Static over disjoint field borrows so both loops can call it
    /// while `tt` points into the trainer's own probe stack.)
    fn accumulate_g(
        g: &mut [f32],
        tt: &[f32],
        c_tilde: f32,
        scales: &Option<LayerScales>,
        amplitude: f32,
    ) {
        match scales {
            Some(s) => {
                for ((g, &t), &ia) in g.iter_mut().zip(tt.iter()).zip(&s.inv_a2) {
                    *g += c_tilde * t * ia;
                }
            }
            None => {
                let inv_a2 = 1.0 / (amplitude * amplitude);
                for (g, &t) in g.iter_mut().zip(tt.iter()) {
                    *g += c_tilde * t * inv_a2;
                }
            }
        }
    }

    /// Lines 15–17: the τθ parameter update (Δθ = −ηG + noise).
    fn apply_theta_update(&mut self) -> Result<()> {
        record_g_norm(&self.g);
        match &self.scales {
            Some(s) => {
                for ((d, &g), &lr) in self.delta.iter_mut().zip(self.g.iter()).zip(&s.lr) {
                    *d = -self.cfg.eta * lr * g;
                }
            }
            None => {
                for (d, &g) in self.delta.iter_mut().zip(self.g.iter()) {
                    *d = -self.cfg.eta * g;
                }
            }
        }
        // §3.5 test 2: stochastic parameter-update noise (Eq. 5).
        self.cfg.noise.apply_update_noise(&mut self.rng, &mut self.delta);
        self.dev.apply_update(&self.delta)?;
        self.g.fill(0.0);
        self.c0_valid = false;
        Ok(())
    }

    /// Execute one MGD timestep (Algorithm 1 loop body).
    ///
    /// For [`PerturbKind::Antithetic`] the baseline eval is skipped
    /// entirely (the pair is its own reference) and the reported
    /// `c_tilde` is `0.0` on even steps, the central difference on odd.
    pub fn step(&mut self) -> Result<StepOutput> {
        // Observe-only: the guard never touches θ, the RNGs, or the
        // device-call order, so traced and untraced runs are
        // bit-identical.  A bare trainer starts its own trace (subject
        // to head sampling); under a traced fleet job it nests instead.
        let _span = if obs::trace::current().is_some() {
            obs::trace::child(obs::trace::name::MGD_STEP)
        } else {
            obs::trace::root(obs::trace::name::MGD_STEP)
        };
        let n = self.step;

        // Lines 3–4: new training sample window every τx.
        self.load_window_if_due(n)?;

        // Lines 5–7: re-measure the baseline cost C₀ (θ̃ = 0) when the
        // sample window or the parameters changed.  Antithetic pairs
        // never measure a baseline: the ± pair is its own reference.
        let m = trainer_metrics();
        let antithetic = self.cfg.kind == PerturbKind::Antithetic;
        if !antithetic && !self.c0_valid {
            self.c0 = self.dev.cost(None)? + self.cfg.noise.cost_noise(&mut self.rng);
            self.cost_evals += 1;
            m.cost_evals.inc();
            self.c0_valid = true;
        }

        // Lines 8–9: advance the perturbation pattern every τp (the
        // generator itself holds the pattern within a τp window).
        self.pert.fill(n, &mut self.tt);
        Self::scale_probe(&self.scales, &mut self.tt);

        // Lines 10–12: perturbed inference, cost, modulation.
        let c = self.dev.cost(Some(&self.tt))? + self.cfg.noise.cost_noise(&mut self.rng);
        self.cost_evals += 1;
        m.cost_evals.inc();
        m.cost.set(c as f64);
        let (c_tilde, accumulate) = self.pair_cost(n, c);

        // Lines 13–14: homodyne error signal, accumulated into G.
        if accumulate {
            Self::accumulate_g(&mut self.g, &self.tt, c_tilde, &self.scales, self.cfg.amplitude);
        }

        // Lines 15–17: parameter update every τθ.
        let updated = self.cfg.tau_theta != u64::MAX
            && (n + 1) % self.cfg.tau_theta.max(1) == 0;
        if updated {
            self.apply_theta_update()?;
        }

        self.step += 1;
        m.steps.inc();
        Ok(StepOutput { step: n, cost: c, c_tilde, updated })
    }

    /// Execute up to `k` timesteps of Algorithm 1 through a **single**
    /// [`HardwareDevice::cost_many`] probe batch.
    ///
    /// The window is clamped to the boundaries inside which batching is
    /// invisible to the algorithm: θ and the loaded sample window must be
    /// constant across every probe of one `cost_many` call, so the window
    /// never crosses a τx sample change or a τθ update (an update *ending*
    /// the window is fine — it fires after the last probe, exactly where
    /// the serial loop fires it).  τp needs no clamp: probes within the
    /// window simply repeat while the pattern holds.
    ///
    /// Within those bounds the result is **bit-identical** to calling
    /// [`MgdTrainer::step`] `k` times: the same perturbation-generator
    /// sequence, the same noise-RNG draw order (one baseline draw when C₀
    /// is re-measured, then one draw per probe in step order, then the
    /// update-noise draws), the same G accumulation order, and the same
    /// `cost_evals` count.  The returned outputs may therefore be shorter
    /// than `k`; callers just call again.
    pub fn step_window(&mut self, k: usize) -> Result<Vec<StepOutput>> {
        if k == 0 {
            return Ok(Vec::new());
        }
        // Observe-only (see `step`): the canonical trainer-side root —
        // the window's `cost_many_rpc` child ships this span's context
        // over the wire, linking the server's lease/dispatch/exec spans
        // into one cross-process timeline.
        let _span = if obs::trace::current().is_some() {
            obs::trace::child(obs::trace::name::STEP_WINDOW)
        } else {
            obs::trace::root(obs::trace::name::STEP_WINDOW)
        };
        let n = self.step;
        let tau_x = self.cfg.tau_x.max(1);
        let mut k_eff = (k as u64).min(tau_x - (n % tau_x));
        if self.cfg.tau_theta != u64::MAX {
            let tau_t = self.cfg.tau_theta.max(1);
            k_eff = k_eff.min(tau_t - (n % tau_t));
        }
        let k_eff = k_eff as usize;

        // Lines 3–4: new training sample window (window start only — the
        // clamp guarantees no τx boundary falls strictly inside).
        self.load_window_if_due(n)?;

        // Lines 5–7: baseline C₀, at most once per window.  Antithetic
        // pairs never measure one (the ± pair is its own reference).
        let m = trainer_metrics();
        let antithetic = self.cfg.kind == PerturbKind::Antithetic;
        if !antithetic && !self.c0_valid {
            self.c0 = self.dev.cost(None)? + self.cfg.noise.cost_noise(&mut self.rng);
            self.cost_evals += 1;
            m.cost_evals.inc();
            self.c0_valid = true;
        }

        // Lines 8–9 for every step of the window: stack the probes.  A
        // multi-probe fill advances the generator past step n, so if the
        // device call below fails the generator must rewind — otherwise
        // a checkpoint-on-failure would resume with probes drawn beyond
        // the replay point and diverge from the uninterrupted run.  A
        // single-probe fill is idempotent (re-filling the same step
        // re-reads the held pattern), so the serial path pays nothing.
        let pert_rewind = if k_eff > 1 { Some(self.pert.export_state()) } else { None };
        let p = self.g.len();
        if self.probes.len() < k_eff * p {
            self.probes.resize(k_eff * p, 0.0);
        }
        for i in 0..k_eff {
            self.pert.fill(n + i as u64, &mut self.probes[i * p..(i + 1) * p]);
            Self::scale_probe(&self.scales, &mut self.probes[i * p..(i + 1) * p]);
        }

        // Lines 10–12, batched: K perturbed inferences, one device call.
        let costs = match self.dev.cost_many(&self.probes[..k_eff * p], k_eff) {
            Ok(costs) => costs,
            Err(e) => {
                if let Some(state) = &pert_rewind {
                    // Same generator, same shape: cannot fail.
                    self.pert
                        .import_state(state)
                        .expect("rewinding perturbation state after device failure");
                }
                return Err(e);
            }
        };
        if costs.len() != k_eff {
            anyhow::bail!(
                "cost_many returned {} costs for {k_eff} probes — device broke the \
                 one-cost-per-probe contract",
                costs.len()
            );
        }
        self.cost_evals += k_eff as u64;
        m.cost_evals.add(k_eff as u64);
        m.probe_window.set(k_eff as f64);

        // Lines 13–17 replayed per step, in step order.
        let mut outs = Vec::with_capacity(k_eff);
        for (i, &raw) in costs.iter().enumerate().take(k_eff) {
            let step = n + i as u64;
            let c = raw + self.cfg.noise.cost_noise(&mut self.rng);
            m.cost.set(c as f64);
            let (c_tilde, accumulate) = self.pair_cost(step, c);
            if accumulate {
                let tt = &self.probes[i * p..(i + 1) * p];
                Self::accumulate_g(&mut self.g, tt, c_tilde, &self.scales, self.cfg.amplitude);
            }
            let updated = self.cfg.tau_theta != u64::MAX
                && (step + 1) % self.cfg.tau_theta.max(1) == 0;
            if updated {
                self.apply_theta_update()?;
            }
            outs.push(StepOutput { step, cost: c, c_tilde, updated });
        }
        self.step += k_eff as u64;
        m.steps.add(k_eff as u64);
        Ok(outs)
    }

    /// Run the training loop with the given stopping/recording options.
    /// `eval_set` provides the accuracy probe (defaults to the training
    /// set for the paper's small problems).
    ///
    /// One device call per timestep — the single-probe case of
    /// [`MgdTrainer::train_batched`], to which this delegates (a width-1
    /// window is exactly one Algorithm 1 step, so there is only one loop
    /// to keep correct).
    pub fn train(
        &mut self,
        opts: &TrainOptions,
        eval_set: Option<&Dataset>,
    ) -> Result<TrainResult> {
        self.train_batched(opts, eval_set, 1)
    }

    /// [`MgdTrainer::train`] driven through [`MgdTrainer::step_window`]:
    /// up to `probes_per_call` timesteps per device call.
    ///
    /// The trajectory — every θ, G, recorded cost, eval and stopping
    /// decision — is identical to the serial loop for any
    /// `probes_per_call` (1 reproduces `train` exactly); only the number
    /// of device calls changes.  Windows are additionally clamped to the
    /// eval cadence so accuracy probes land between windows, exactly
    /// where the serial loop takes them.
    pub fn train_batched(
        &mut self,
        opts: &TrainOptions,
        eval_set: Option<&Dataset>,
        probes_per_call: usize,
    ) -> Result<TrainResult> {
        let k_max = probes_per_call.max(1) as u64;
        let eval = eval_set.unwrap_or(self.dataset);
        let mut result = TrainResult::default();
        'windows: while self.step < opts.max_steps {
            let mut k = k_max.min(opts.max_steps - self.step);
            if opts.eval_every > 0 {
                k = k.min(opts.eval_every - (self.step % opts.eval_every));
            }
            let outs = self.step_window(k as usize)?;
            for out in &outs {
                if opts.record_cost_every > 0 && out.step % opts.record_cost_every == 0 {
                    result.cost_trace.push((out.step, out.cost));
                }
                let check = opts.eval_every > 0 && (out.step + 1) % opts.eval_every == 0;
                if check {
                    let (cost, correct) = self.evaluate_on(eval)?;
                    let acc = correct / eval.n as f32;
                    result.eval_trace.push((out.step, cost, acc));
                    let cost_hit = opts.target_cost.is_some_and(|t| cost < t);
                    let acc_hit = opts.target_accuracy.is_some_and(|t| acc >= t);
                    if cost_hit || acc_hit {
                        result.solved_at = Some(out.step);
                        break 'windows;
                    }
                }
            }
        }
        result.steps_run = self.step;
        result.cost_evals = self.cost_evals;
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::xor;
    use crate::device::NativeDevice;
    use crate::optim::init_params_uniform;
    use crate::perturb::PerturbKind;

    fn xor_device(seed: u64) -> NativeDevice {
        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        let mut rng = Rng::new(seed);
        let mut theta = vec![0f32; 9];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta).unwrap();
        dev
    }

    #[test]
    fn solves_xor_with_spsa_settings() {
        // Paper Table 2 row 1: XOR with τθ = τp = 1 and η ≈ 5 solves
        // reliably within 10⁴ steps.  Use a couple of seeds; at least one
        // must solve quickly and none may blow up.
        let data = xor();
        let mut solved_any = false;
        for seed in 0..3u64 {
            let mut dev = xor_device(seed);
            let cfg = MgdConfig {
                eta: 2.0,
                amplitude: 0.05,
                kind: PerturbKind::RademacherCode,
                seed,
                ..Default::default()
            };
            let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            let opts = TrainOptions {
                max_steps: 60_000,
                eval_every: 500,
                target_cost: Some(0.04),
                ..Default::default()
            };
            let res = tr.train(&opts, None).unwrap();
            assert!(res.steps_run > 0);
            if res.solved() {
                solved_any = true;
            }
        }
        assert!(solved_any, "no seed solved XOR within the budget");
    }

    #[test]
    fn infinite_tau_theta_never_updates() {
        let data = xor();
        let mut dev = xor_device(1);
        let theta_before = dev.get_params().unwrap();
        let cfg = MgdConfig { tau_theta: u64::MAX, seed: 1, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..100 {
            let out = tr.step().unwrap();
            assert!(!out.updated);
        }
        assert!(tr.gradient().iter().any(|&g| g != 0.0), "G never accumulated");
        assert_eq!(dev.get_params().unwrap(), theta_before);
    }

    #[test]
    fn gradient_estimate_correlates_with_true_gradient() {
        // Homodyne G (τθ=∞) must point in the same half-space as the true
        // gradient after enough integration — the core Eq. 3 property.
        let data = xor();
        let mut dev = xor_device(3);
        let theta = dev.get_params().unwrap();
        let cfg = MgdConfig {
            tau_theta: u64::MAX,
            amplitude: 0.01,
            seed: 3,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..4000 {
            tr.step().unwrap();
        }
        let g = tr.gradient().to_vec();
        // Finite-difference true gradient of the mean dataset cost.
        let mut true_g = vec![0f32; 9];
        let eps = 1e-3f32;
        let mut dev2 = NativeDevice::new(&[2, 2, 1], 4);
        dev2.set_params(&theta).unwrap();
        dev2.load_batch(&data.x, &data.y).unwrap();
        let base = dev2.cost(None).unwrap();
        for i in 0..9 {
            let mut tt = vec![0f32; 9];
            tt[i] = eps;
            true_g[i] = (dev2.cost(Some(&tt)).unwrap() - base) / eps;
        }
        let dot: f32 = g.iter().zip(&true_g).map(|(a, b)| a * b).sum();
        let na: f32 = g.iter().map(|v| v * v).sum::<f32>().sqrt();
        let nb: f32 = true_g.iter().map(|v| v * v).sum::<f32>().sqrt();
        let cos = dot / (na * nb);
        assert!(cos > 0.5, "G misaligned with true gradient: cos = {cos}");
    }

    #[test]
    fn tau_theta_controls_update_cadence() {
        let data = xor();
        let mut dev = xor_device(2);
        let cfg = MgdConfig { tau_theta: 5, seed: 2, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let mut updates = Vec::new();
        for _ in 0..20 {
            let out = tr.step().unwrap();
            if out.updated {
                updates.push(out.step);
            }
        }
        assert_eq!(updates, vec![4, 9, 14, 19]);
    }

    #[test]
    fn sync_params_overwrites_and_resets_state() {
        let data = xor();
        let mut dev = xor_device(6);
        let cfg = MgdConfig { tau_theta: u64::MAX, seed: 6, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..10 {
            tr.step().unwrap();
        }
        assert!(tr.gradient().iter().any(|&g| g != 0.0));
        tr.sync_params(&[0.25; 9]).unwrap();
        assert!(tr.gradient().iter().all(|&g| g == 0.0), "G must reset on sync");
        assert_eq!(tr.device_params().unwrap(), vec![0.25; 9]);
        let (cost, correct) = tr.evaluate_on(&data).unwrap();
        assert!(cost.is_finite() && correct <= data.n as f32);
        // Training continues cleanly after the sync.
        tr.step().unwrap();
    }

    #[test]
    fn step_window_clamps_to_tau_boundaries() {
        let data = xor();
        let mut dev = xor_device(8);
        // τx = 3, τθ = 4: windows may never cross a sample change or an
        // update, so a greedy k=10 request shrinks to the next boundary.
        let cfg = MgdConfig { tau_x: 3, tau_theta: 4, seed: 8, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        // step 0: boundaries at step 3 (τx) and after step 3 (τθ) → 3 steps.
        assert_eq!(tr.step_window(10).unwrap().len(), 3);
        // step 3: τθ boundary after step 3 → exactly 1 step, which updates.
        let outs = tr.step_window(10).unwrap();
        assert_eq!(outs.len(), 1);
        assert!(outs[0].updated);
        // step 4: next τx change at step 6 → 2 steps.
        assert_eq!(tr.step_window(10).unwrap().len(), 2);
        assert_eq!(tr.steps(), 6);
        // k = 0 is a no-op.
        assert!(tr.step_window(0).unwrap().is_empty());
        assert_eq!(tr.steps(), 6);
    }

    #[test]
    fn step_window_matches_serial_steps_bitwise() {
        let data = xor();
        let cfg = MgdConfig {
            eta: 1.5,
            amplitude: 0.05,
            tau_x: 3,
            tau_theta: 4,
            seed: 12,
            ..Default::default()
        };
        let mut dev_a = xor_device(12);
        let mut dev_b = xor_device(12);
        let mut serial = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
        let mut windowed = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
        let mut serial_outs = Vec::new();
        for _ in 0..60 {
            serial_outs.push(serial.step().unwrap());
        }
        let mut windowed_outs = Vec::new();
        for k in [5usize, 1, 7, 2, 11].iter().cycle() {
            if windowed.steps() >= 60 {
                break;
            }
            let k = (*k).min(60 - windowed.steps() as usize);
            windowed_outs.extend(windowed.step_window(k).unwrap());
        }
        assert_eq!(serial_outs.len(), windowed_outs.len());
        for (s, w) in serial_outs.iter().zip(&windowed_outs) {
            assert_eq!(s.step, w.step);
            assert_eq!(s.cost.to_bits(), w.cost.to_bits(), "step {}", s.step);
            assert_eq!(s.c_tilde.to_bits(), w.c_tilde.to_bits(), "step {}", s.step);
            assert_eq!(s.updated, w.updated, "step {}", s.step);
        }
        assert_eq!(serial.cost_evals(), windowed.cost_evals());
        let ga: Vec<u32> = serial.gradient().iter().map(|g| g.to_bits()).collect();
        let gb: Vec<u32> = windowed.gradient().iter().map(|g| g.to_bits()).collect();
        assert_eq!(ga, gb, "gradient integrators diverged");
        let ta: Vec<u32> =
            serial.device_params().unwrap().iter().map(|t| t.to_bits()).collect();
        let tb: Vec<u32> =
            windowed.device_params().unwrap().iter().map(|t| t.to_bits()).collect();
        assert_eq!(ta, tb, "parameter memories diverged");
    }

    #[test]
    fn failed_window_salvage_resumes_bit_identically() {
        // A multi-probe window that dies in the device call must leave a
        // checkpointable state that resumes onto the uninterrupted
        // trajectory: the schedule must not be re-consumed and the
        // perturbation generator must rewind the probes it pre-drew for
        // steps that never ran.  Exercise both stateful generators.
        use crate::device::{FlakyConfig, FlakyDevice};
        for kind in [PerturbKind::RademacherCode, PerturbKind::Sinusoidal] {
            let data = xor();
            let cfg = MgdConfig {
                tau_x: 6,
                tau_theta: 6,
                tau_p: 2,
                eta: 0.5,
                amplitude: 0.05,
                kind,
                noise: crate::noise::NoiseConfig { sigma_cost: 0.01, sigma_update: 0.002 },
                seed: 21,
            };
            let opts = TrainOptions { max_steps: 60, ..Default::default() };

            // Reference: uninterrupted run, 6-probe windows.
            let mut dev_ref = xor_device(9);
            let mut tr_ref = MgdTrainer::new(&mut dev_ref, &data, cfg, ScheduleKind::Cyclic);
            tr_ref.train_batched(&opts, None, 6).unwrap();

            // Interrupted: per window the device sees C₀ + one CostMany,
            // so the 4th cost measurement is window 2's probe batch —
            // it fails mid-window, after C₀ and the probe fill.
            let mut flaky = FlakyDevice::new(
                Box::new(xor_device(9)),
                FlakyConfig { fail_after: Some(3), ..Default::default() },
            );
            let snap = {
                let mut tr = MgdTrainer::new(&mut flaky, &data, cfg, ScheduleKind::Cyclic);
                let err = tr.train_batched(&opts, None, 6).unwrap_err();
                assert!(err.to_string().contains("injected fault"), "{err:#}");
                assert_eq!(tr.steps(), 6, "{kind:?}: failure lands inside window 2");
                tr.checkpoint().unwrap()
            };
            assert_eq!(flaky.cost_calls(), 4);

            // "New process": fresh device, fresh trainer, restore, finish.
            let mut dev_b = xor_device(9);
            let mut tr_b = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
            tr_b.restore(&snap).unwrap();
            tr_b.train_batched(&opts, None, 6).unwrap();

            assert_eq!(tr_ref.cost_evals(), tr_b.cost_evals(), "{kind:?} cost_evals");
            let gb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(gb(tr_ref.gradient()), gb(tr_b.gradient()), "{kind:?} G diverged");
            assert_eq!(
                gb(&tr_ref.device_params().unwrap()),
                gb(&tr_b.device_params().unwrap()),
                "{kind:?} θ diverged after failed-window salvage"
            );
        }
    }

    #[test]
    fn cost_evals_track_baseline_caching() {
        let data = xor();
        let mut dev = xor_device(4);
        // τx = 10, τθ = MAX: baseline measured once per sample window.
        let cfg = MgdConfig {
            tau_x: 10,
            tau_theta: u64::MAX,
            seed: 4,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..20 {
            tr.step().unwrap();
        }
        // 20 perturbed + 2 baselines (steps 0 and 10).
        assert_eq!(tr.cost_evals(), 22);
    }

    #[test]
    fn antithetic_window_matches_serial_and_skips_baseline() {
        let data = xor();
        let cfg = MgdConfig {
            eta: 1.5,
            amplitude: 0.05,
            tau_x: 6,
            tau_theta: 6,
            kind: PerturbKind::Antithetic,
            noise: crate::noise::NoiseConfig { sigma_cost: 0.01, sigma_update: 0.002 },
            seed: 17,
            ..Default::default()
        };
        let mut dev_a = xor_device(17);
        let mut dev_b = xor_device(17);
        let mut serial = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
        let mut windowed = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
        let mut serial_outs = Vec::new();
        for _ in 0..36 {
            serial_outs.push(serial.step().unwrap());
        }
        let mut windowed_outs = Vec::new();
        for k in [4usize, 1, 6, 3].iter().cycle() {
            if windowed.steps() >= 36 {
                break;
            }
            let k = (*k).min(36 - windowed.steps() as usize);
            windowed_outs.extend(windowed.step_window(k).unwrap());
        }
        assert_eq!(serial_outs.len(), windowed_outs.len());
        for (s, w) in serial_outs.iter().zip(&windowed_outs) {
            assert_eq!(s.cost.to_bits(), w.cost.to_bits(), "step {}", s.step);
            assert_eq!(s.c_tilde.to_bits(), w.c_tilde.to_bits(), "step {}", s.step);
            assert_eq!(s.updated, w.updated, "step {}", s.step);
        }
        // No C₀ baseline anywhere: one eval per step exactly.
        assert_eq!(serial.cost_evals(), 36);
        assert_eq!(windowed.cost_evals(), 36);
        // Even steps park the pair (c̃ = 0), odd steps close it.
        assert!(serial_outs.iter().step_by(2).all(|o| o.c_tilde == 0.0));
        assert!(serial_outs.iter().skip(1).step_by(2).any(|o| o.c_tilde != 0.0));
        let ta: Vec<u32> =
            serial.device_params().unwrap().iter().map(|t| t.to_bits()).collect();
        let tb: Vec<u32> =
            windowed.device_params().unwrap().iter().map(|t| t.to_bits()).collect();
        assert_eq!(ta, tb, "antithetic parameter memories diverged");
    }

    #[test]
    fn antithetic_rejects_pair_splitting_cadences() {
        let data = xor();
        for (tau_x, tau_theta) in [(3u64, 6u64), (6, 5)] {
            let mut dev = xor_device(0);
            let cfg = MgdConfig {
                tau_x,
                tau_theta,
                kind: PerturbKind::Antithetic,
                ..Default::default()
            };
            let err = MgdTrainer::try_new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            assert!(err.is_err(), "τx={tau_x}, τθ={tau_theta} must be rejected");
        }
        // Even τx with τθ = ∞ is the integration configuration — fine.
        let mut dev = xor_device(0);
        let cfg = MgdConfig {
            tau_x: 2,
            tau_theta: u64::MAX,
            kind: PerturbKind::Antithetic,
            ..Default::default()
        };
        assert!(MgdTrainer::try_new(&mut dev, &data, cfg, ScheduleKind::Cyclic).is_ok());
    }

    #[test]
    fn sparse_kinds_window_matches_serial_bitwise() {
        let data = xor();
        for kind in [PerturbKind::LayerSparse, PerturbKind::BlockSparse { block: 4 }] {
            let cfg = MgdConfig {
                eta: 1.5,
                amplitude: 0.05,
                tau_x: 3,
                tau_theta: 4,
                tau_p: 2,
                kind,
                seed: 23,
                ..Default::default()
            };
            let mut dev_a = xor_device(23);
            let mut dev_b = xor_device(23);
            let mut serial = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
            let mut windowed = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
            for _ in 0..48 {
                serial.step().unwrap();
            }
            while windowed.steps() < 48 {
                windowed.step_window(5).unwrap();
            }
            assert_eq!(serial.cost_evals(), windowed.cost_evals(), "{kind:?}");
            let gb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(gb(serial.gradient()), gb(windowed.gradient()), "{kind:?} G diverged");
            assert_eq!(
                gb(&serial.device_params().unwrap()),
                gb(&windowed.device_params().unwrap()),
                "{kind:?} θ diverged"
            );
        }
    }

    #[test]
    fn identity_layer_schedule_is_bit_identical_to_none() {
        let data = xor();
        let cfg =
            MgdConfig { eta: 2.0, amplitude: 0.05, tau_theta: 4, seed: 31, ..Default::default() };
        let mut dev_a = xor_device(31);
        let mut dev_b = xor_device(31);
        let mut plain = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
        let mut scheduled = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
        let sched = PerLayerSchedule::new(vec![1.0, 1.0], vec![1.0, 1.0]).unwrap();
        scheduled.set_layer_schedule(&sched).unwrap();
        for _ in 0..24 {
            let a = plain.step().unwrap();
            let b = scheduled.step().unwrap();
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "step {}", a.step);
            assert_eq!(a.c_tilde.to_bits(), b.c_tilde.to_bits(), "step {}", a.step);
        }
        let gb = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(gb(plain.gradient()), gb(scheduled.gradient()));
        assert_eq!(
            gb(&plain.device_params().unwrap()),
            gb(&scheduled.device_params().unwrap()),
            "an all-1.0 schedule must be a bitwise no-op"
        );
    }

    #[test]
    fn real_layer_schedule_changes_the_trajectory() {
        let data = xor();
        let cfg =
            MgdConfig { eta: 2.0, amplitude: 0.05, tau_theta: 4, seed: 32, ..Default::default() };
        let mut dev_a = xor_device(32);
        let mut dev_b = xor_device(32);
        let mut plain = MgdTrainer::new(&mut dev_a, &data, cfg, ScheduleKind::Cyclic);
        let mut scheduled = MgdTrainer::new(&mut dev_b, &data, cfg, ScheduleKind::Cyclic);
        let sched = PerLayerSchedule::new(vec![1.0, 0.25], vec![1.0, 0.5]).unwrap();
        scheduled.set_layer_schedule(&sched).unwrap();
        assert_eq!(scheduled.layer_schedule(), Some(&sched));
        for _ in 0..8 {
            plain.step().unwrap();
            scheduled.step().unwrap();
        }
        assert_ne!(
            plain.device_params().unwrap(),
            scheduled.device_params().unwrap(),
            "a non-identity schedule must change the update"
        );
        // Wrong layer count is rejected; so is installing mid-run.
        let bad = PerLayerSchedule::new(vec![1.0, 0.5, 0.25], vec![1.0]).unwrap();
        assert!(scheduled.set_layer_schedule(&bad).is_err());
        assert!(plain.set_layer_schedule(&sched).is_err(), "mid-run install must fail");
    }

    #[test]
    fn trainer_metrics_advance_with_steps() {
        // The registry is process-global and other tests train too, so
        // only ≥-deltas on the counters are stable assertions.
        let steps_before = crate::obs::counter("mgd_trainer_steps_total").get();
        let evals_before = crate::obs::counter("mgd_trainer_cost_evals_total").get();
        let data = xor();
        let mut dev = xor_device(5);
        let cfg = MgdConfig { seed: 5, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..8 {
            tr.step().unwrap();
        }
        tr.evaluate_on(&data).unwrap();
        assert!(crate::obs::counter("mgd_trainer_steps_total").get() >= steps_before + 8);
        assert!(crate::obs::counter("mgd_trainer_cost_evals_total").get() >= evals_before + 8);
        let acc = crate::obs::gauge("mgd_trainer_eval_accuracy").get();
        assert!((0.0..=1.0).contains(&acc), "accuracy gauge out of range: {acc}");
    }
}
