//! Algorithm 2 — the continuous-time (analog) MGD loop.
//!
//! The analog variant replaces every discrete mechanism with its circuit
//! equivalent (Fig. 2d, §4.2):
//!
//! | discrete (Algorithm 1)        | analog (Algorithm 2)                  |
//! |-------------------------------|---------------------------------------|
//! | store C₀, subtract            | highpass filter on C (τ_hp)           |
//! | accumulate G, reset every τθ  | per-parameter lowpass bank (τθ)       |
//! | update θ every τθ             | continuous update θ ← θ − ηG every dt |
//! | discrete perturbation codes   | sinusoidal perturbations (bandwidth Δf)|
//!
//! The simulation step `dt` is 1 (one inference time); time constants are
//! expressed in the same unit.

use anyhow::Result;

use super::schedule::{SampleSchedule, ScheduleKind};
use super::{TrainOptions, TrainResult};
use crate::datasets::Dataset;
use crate::device::HardwareDevice;
use crate::filters::{Highpass, LowpassBank};
use crate::noise::NoiseConfig;
use crate::perturb::{Perturbation, Sinusoidal};
use crate::rng::Rng;

/// Configuration for the analog loop (Algorithm 2's knobs).
#[derive(Debug, Clone, Copy)]
pub struct AnalogConfig {
    /// τx: timesteps between sample changes.
    pub tau_x: u64,
    /// τθ: lowpass (gradient-integration) time constant.
    pub tau_theta: f64,
    /// τ_hp: highpass time constant at the cost output.
    pub tau_hp: f64,
    /// Perturbation bandwidth Δf expressed through an equivalent τp
    /// (`Δf = 1/τp`; see §2.2's analog discussion).
    pub tau_p: u64,
    /// η: learning rate.
    pub eta: f32,
    /// Δθ: perturbation amplitude.
    pub amplitude: f32,
    /// Cost/update noise (§3.5).
    pub noise: NoiseConfig,
    pub seed: u64,
}

impl Default for AnalogConfig {
    fn default() -> Self {
        AnalogConfig {
            tau_x: 1,
            tau_theta: 10.0,
            tau_hp: 100.0,
            tau_p: 1,
            eta: 1.0,
            amplitude: 0.01,
            noise: NoiseConfig::none(),
            seed: 0,
        }
    }
}

/// One analog timestep's observables (for the Fig. 2d trace).
#[derive(Debug, Clone, Copy)]
pub struct AnalogStep {
    pub step: u64,
    /// Raw cost C(t).
    pub cost: f32,
    /// Highpassed cost modulation C̃(t).
    pub c_tilde: f32,
}

/// Continuous-time MGD trainer (Algorithm 2) over a black-box device.
pub struct AnalogTrainer<'d> {
    dev: &'d mut dyn HardwareDevice,
    cfg: AnalogConfig,
    pert: Sinusoidal,
    schedule: SampleSchedule,
    dataset: &'d Dataset,
    highpass: Highpass,
    lowpass: LowpassBank,
    g: Vec<f32>,
    e: Vec<f32>,
    tt: Vec<f32>,
    delta: Vec<f32>,
    rng: Rng,
    step: u64,
}

impl<'d> AnalogTrainer<'d> {
    pub fn new(
        dev: &'d mut dyn HardwareDevice,
        dataset: &'d Dataset,
        cfg: AnalogConfig,
        schedule_kind: ScheduleKind,
    ) -> Self {
        let p = dev.n_params();
        let batch = dev.batch_size();
        let schedule = SampleSchedule::new(dataset, batch, schedule_kind, cfg.seed);
        AnalogTrainer {
            dev,
            pert: Sinusoidal::new(p, cfg.amplitude, cfg.tau_p),
            schedule,
            dataset,
            highpass: Highpass::new(cfg.tau_hp, 1.0),
            lowpass: LowpassBank::new(p, cfg.tau_theta, 1.0),
            g: vec![0.0; p],
            e: vec![0.0; p],
            tt: vec![0.0; p],
            delta: vec![0.0; p],
            rng: Rng::new(cfg.seed ^ 0x4d47_4432), // "MGD2"
            cfg,
            step: 0,
        }
    }

    /// Current (lowpassed) gradient approximation G(t).
    pub fn gradient(&self) -> &[f32] {
        &self.g
    }

    /// Snapshot the device's parameter memory (trace harnesses).
    pub fn device_params(&mut self) -> Result<Vec<f32>> {
        self.dev.get_params()
    }

    /// One dt of Algorithm 2.
    pub fn step(&mut self) -> Result<AnalogStep> {
        let t = self.step;
        // Line 3–4: sample window.
        if t % self.cfg.tau_x.max(1) == 0 {
            let idx = self.schedule.next_window();
            let (xb, yb) = self.dataset.gather(&idx);
            self.dev.load_batch(&xb, &yb)?;
        }
        // Line 5–7: perturbation + perturbed inference + cost.
        self.pert.fill(t, &mut self.tt);
        let c = self.dev.cost(Some(&self.tt))? + self.cfg.noise.cost_noise(&mut self.rng);
        // Line 8: highpass extracts C̃ (no C₀ memory anywhere).
        let c_tilde = self.highpass.step(c as f64) as f32;
        // Line 9: instantaneous error signal e(t) = C̃ θ̃ dt / Δθ².
        let inv_a2 = 1.0 / (self.cfg.amplitude * self.cfg.amplitude);
        for (e, &tt) in self.e.iter_mut().zip(self.tt.iter()) {
            *e = c_tilde * tt * inv_a2;
        }
        // Line 10: lowpass bank integrates e into G.
        let e = std::mem::take(&mut self.e);
        self.lowpass.step(&e, &mut self.g);
        self.e = e;
        // Line 11: continuous parameter update.
        for (d, &g) in self.delta.iter_mut().zip(self.g.iter()) {
            *d = -self.cfg.eta * g;
        }
        self.cfg.noise.apply_update_noise(&mut self.rng, &mut self.delta);
        self.dev.apply_update(&self.delta)?;
        self.step += 1;
        Ok(AnalogStep { step: t, cost: c, c_tilde })
    }

    /// Run with the shared stopping/recording options.
    pub fn train(&mut self, opts: &TrainOptions, eval_set: Option<&Dataset>) -> Result<TrainResult> {
        let eval = eval_set.unwrap_or(self.dataset);
        let mut result = TrainResult::default();
        while self.step < opts.max_steps {
            let out = self.step()?;
            if opts.record_cost_every > 0 && out.step % opts.record_cost_every == 0 {
                result.cost_trace.push((out.step, out.cost));
            }
            if opts.eval_every > 0 && (out.step + 1) % opts.eval_every == 0 {
                let (cost, correct) = self.dev.evaluate(&eval.x, &eval.y, eval.n)?;
                let acc = correct / eval.n as f32;
                result.eval_trace.push((out.step, cost, acc));
                let cost_hit = opts.target_cost.is_some_and(|v| cost < v);
                let acc_hit = opts.target_accuracy.is_some_and(|v| acc >= v);
                if cost_hit || acc_hit {
                    result.solved_at = Some(out.step);
                    break;
                }
            }
        }
        result.steps_run = self.step;
        result.cost_evals = self.step;
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::xor;
    use crate::device::NativeDevice;
    use crate::optim::init_params_uniform;

    #[test]
    fn analog_loop_reduces_xor_cost() {
        // Fig. 7's analog configuration solves XOR; we require solid cost
        // reduction within a modest budget for at least one of two seeds.
        // Hyper-parameters from the calibration sweep recorded in
        // EXPERIMENTS.md (amp 0.1, τ_hp 10, η 0.1 solves 7/8 seeds within
        // 250k steps); the unit test uses 2 seeds and a reduced budget.
        let data = xor();
        let mut improved = false;
        for seed in [0u64, 1] {
            let mut dev = NativeDevice::new(&[2, 2, 1], 1);
            let mut theta = vec![0f32; 9];
            init_params_uniform(&mut Rng::new(seed), &mut theta, 1.0);
            dev.set_params(&theta).unwrap();
            let (c_start, _) = dev.evaluate(&data.x, &data.y, data.n).unwrap();
            let cfg = AnalogConfig {
                tau_x: 250,
                tau_theta: 1.0,
                tau_hp: 10.0,
                tau_p: 3,
                eta: 0.1,
                amplitude: 0.1,
                seed,
                ..Default::default()
            };
            let mut tr = AnalogTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            let opts = TrainOptions {
                max_steps: 120_000,
                eval_every: 1000,
                target_cost: Some(0.04),
                ..Default::default()
            };
            let res = tr.train(&opts, None).unwrap();
            let (c_end, _) = dev.evaluate(&data.x, &data.y, data.n).unwrap();
            if res.solved() || c_end < 0.6 * c_start {
                improved = true;
            }
        }
        assert!(improved, "analog MGD failed to reduce cost on both seeds");
    }

    #[test]
    fn highpass_keeps_gradient_bounded() {
        // With a constant input (τx huge) the DC part of C must not leak
        // into G: after a settling period G stays bounded near zero for a
        // device at a local minimum (zero-ish perturbation response).
        let data = xor();
        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        dev.set_params(&[0.0; 9]).unwrap();
        let cfg = AnalogConfig {
            tau_x: u64::MAX >> 1,
            eta: 0.0, // observe only
            amplitude: 1e-4,
            ..Default::default()
        };
        let mut tr = AnalogTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        for _ in 0..2000 {
            tr.step().unwrap();
        }
        let gnorm: f32 = tr.gradient().iter().map(|g| g * g).sum::<f32>().sqrt();
        assert!(gnorm < 1.0, "DC leaked into analog G: |G| = {gnorm}");
    }
}
