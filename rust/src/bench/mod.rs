//! Micro-benchmark harness — the in-repo substrate replacing criterion
//! (offline build; see Cargo.toml).
//!
//! Warmup, adaptive iteration counts, and robust statistics (median +
//! median absolute deviation).  Benches are plain `fn main()` binaries
//! (`[[bench]] harness = false`) that call [`Bench::run`].

use std::time::{Duration, Instant};

/// One benchmark group with shared settings.
pub struct Bench {
    /// Minimum measured wall-clock per sample batch.
    pub min_sample_time: Duration,
    /// Number of sample batches collected per benchmark.
    pub samples: usize,
    /// Warmup duration.
    pub warmup: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            min_sample_time: Duration::from_millis(200),
            samples: 10,
            warmup: Duration::from_millis(300),
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct Measurement {
    /// Median time per iteration (seconds).
    pub median: f64,
    /// Median absolute deviation (seconds).
    pub mad: f64,
    /// Total iterations measured.
    pub iters: u64,
}

impl Measurement {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        1.0 / self.median
    }
}

/// True when the quick-mode env toggle is set (`MGD_BENCH_QUICK=1`):
/// benches shrink their sweeps so the nightly CI bench job finishes in
/// minutes while still producing every metric.
pub fn quick_mode() -> bool {
    std::env::var("MGD_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// Build a JSON object from key/value pairs (bench-record helper).
pub fn json_obj(pairs: Vec<(&str, crate::json::Json)>) -> crate::json::Json {
    let mut m = std::collections::BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    crate::json::Json::Obj(m)
}

/// Append one bench record as a JSONL line to the file named by
/// `MGD_BENCH_JSON` (no-op when unset; the CI bench workflow merges the
/// lines into `BENCH_fleet.json`).  Never fails the bench: a broken sink
/// is reported to stderr and ignored.
pub fn emit_bench_json(record: &crate::json::Json) {
    let Ok(path) = std::env::var("MGD_BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write;
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(&path)
        .and_then(|mut f| writeln!(f, "{}", record.dump()));
    if let Err(e) = appended {
        eprintln!("warning: could not append bench record to {path}: {e}");
    }
}

/// Render seconds/iteration in a readable unit.
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

impl Bench {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bench {
            min_sample_time: Duration::from_millis(100),
            samples: 5,
            warmup: Duration::from_millis(100),
        }
    }

    /// Run `f` repeatedly, print a criterion-style line, return stats.
    ///
    /// `f` performs ONE logical iteration per call and returns a value
    /// that is black-boxed to prevent dead-code elimination.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> Measurement {
        // Warmup + calibration: how many iters fit in min_sample_time?
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let batch = ((self.min_sample_time.as_secs_f64() / per_iter).ceil() as u64).max(1);

        let mut sample_times = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            sample_times.push(t0.elapsed().as_secs_f64() / batch as f64);
            total_iters += batch;
        }
        sample_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sample_times[sample_times.len() / 2];
        let mut devs: Vec<f64> = sample_times.iter().map(|t| (t - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = devs[devs.len() / 2];
        let m = Measurement { median, mad, iters: total_iters };
        println!(
            "bench {name:<44} {:>12}/iter (±{}, {} iters, {:.1} iter/s)",
            fmt_time(m.median),
            fmt_time(m.mad),
            m.iters,
            m.throughput()
        );
        m
    }

    /// Run a one-shot measurement (for long end-to-end runs where
    /// repetition is impractical): times a single call of `f`.
    pub fn once<R, F: FnOnce() -> R>(&self, name: &str, f: F) -> (R, f64) {
        let t0 = Instant::now();
        let r = std::hint::black_box(f());
        let secs = t0.elapsed().as_secs_f64();
        println!("bench {name:<44} {:>12} (single run)", fmt_time(secs));
        (r, secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_sleep() {
        let b = Bench {
            min_sample_time: Duration::from_millis(5),
            samples: 3,
            warmup: Duration::from_millis(2),
        };
        let m = b.run("sleep_1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(m.median > 0.0008 && m.median < 0.01, "median {}", m.median);
        assert!(m.iters >= 3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(3e-9).ends_with("ns"));
        assert!(fmt_time(3e-6).ends_with("us"));
        assert!(fmt_time(3e-3).ends_with("ms"));
        assert!(fmt_time(3.0).ends_with(" s"));
    }

    #[test]
    fn once_returns_value() {
        let b = Bench::quick();
        let (v, secs) = b.once("trivial", || 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
