//! Table 2 end-to-end bench: MGD training throughput per model.
//!
//! Measures fused on-chip window time for every model in the table and
//! reports per-MGD-step wall-clock — the number that, multiplied by the
//! paper's step counts, gives this testbed's equivalent of Table 3.

use mgd::bench::{fmt_time, Bench};
use mgd::coordinator::{MgdConfig, OnChipTrainer};
use mgd::datasets::{nist7x7, parity, synthetic_cifar, synthetic_fmnist, Dataset};
use mgd::optim::init_params;
use mgd::rng::Rng;
use mgd::runtime::Runtime;

fn dataset_for(model: &str, seed: u64) -> Dataset {
    match model {
        "xor221" => parity(2),
        "parity441" => parity(4),
        "nist744" => nist7x7(8192, seed),
        "fmnist_cnn" => synthetic_fmnist(2048, seed),
        "cifar_cnn" => synthetic_cifar(1024, seed),
        _ => unreachable!(),
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(mgd::find_artifact_dir()?)?;
    let b = Bench::quick();
    println!("model        window(T x B)      time/window     time/step   samples/s");
    for model in ["xor221", "parity441", "nist744", "fmnist_cnn", "cifar_cnn"] {
        let meta = rt.manifest.model(model)?.clone();
        let data = dataset_for(model, 42);
        let mut rng = Rng::new(42);
        let mut theta = vec![0f32; meta.param_count];
        init_params(&mut rng, &meta.tensors, &mut theta);
        let cfg = MgdConfig { eta: 0.05, amplitude: 0.01, seed: 42, ..Default::default() };
        let mut tr = OnChipTrainer::new(&rt, model, &data, theta, cfg)?;
        let m = b.run(&format!("table2/{model}"), || tr.window().unwrap()[0]);
        let per_step = m.median / meta.scan_steps as f64;
        // Each MGD step runs 2 inferences over B samples.
        let samples_per_s = 2.0 * meta.scan_batch as f64 / per_step;
        println!(
            "{:<12} {:>5} x {:<6} {:>14} {:>12}  {:>10.0}",
            model,
            meta.scan_steps,
            meta.scan_batch,
            fmt_time(m.median),
            fmt_time(per_step),
            samples_per_s
        );
    }
    Ok(())
}
