//! Inference serving throughput: requests/sec, inferences (rows)/sec and
//! latency percentiles vs the rows-per-request batch size.
//!
//! Four sections:
//!
//! 1. **engine-direct** — the forward executor alone, no wire: rows/sec
//!    at batch 1/8/64 (the pure amortization of the per-forward fixed
//!    cost over the rows of a batch).
//! 2. **quantized** — the int8 `QuantizedEngine` vs the f32 engine at
//!    batch 64 (the rows/sec ratio is the nightly >= 1.5x gate) plus
//!    the seeded fidelity numbers: argmax agreement and mean |Δlogit|.
//! 3. **served (loopback TCP)** — a full `serve_infer` endpoint queried
//!    by an `InferenceClient` at batch 1/8/64, measuring req/s, rows/s
//!    and p50/p99 request latency.  The acceptance bar for the serving
//!    subsystem is rows/sec at batch 64 ≥ 4× rows/sec at batch 1 on the
//!    same engine — the same per-dispatch batching discipline that the
//!    `CostMany` probe engine proved on the training side.
//! 4. **sessions** — throughput vs concurrent sessions (1/8/64/256),
//!    with the active set capped so the sweep grows the *idle* majority:
//!    on the event-loop session layer an idle session is a slab slot,
//!    not a thread, so the curve should stay flat.
//!
//! ```text
//! cargo bench --bench infer_throughput
//! ```
//!
//! Env toggles (the nightly CI bench job sets both):
//! `MGD_BENCH_QUICK=1` shrinks the sweep; `MGD_BENCH_JSON=path` appends
//! one JSONL record (merged into `BENCH_infer.json` by the workflow).

use std::net::TcpListener;
use std::time::Instant;

use mgd::bench::{emit_bench_json, json_obj, quick_mode};
use mgd::device::exec::ForwardScratch;
use mgd::json::Json;
use mgd::model::ModelSpec;
use mgd::rng::Rng;
use mgd::serve::quant::{self, QuantScratch};
use mgd::serve::{
    batcher::percentile_ms, serve_infer, BatchPolicy, InferenceClient, InferenceEngine,
    QuantizedEngine, ServeInferOptions,
};

/// Rows-per-request sweep (the acceptance criterion compares the ends).
const BATCH_SIZES: &[usize] = &[1, 8, 64];

/// A mid-size spec-model engine (NIST7x7-port shape scaled up).
fn bench_engine() -> InferenceEngine {
    let spec: ModelSpec = "49x64x32x4:relu,tanh,softmax".parse().unwrap();
    let mut rng = Rng::new(13);
    let mut theta = vec![0f32; spec.param_count()];
    rng.fill_uniform(&mut theta, -1.0, 1.0);
    InferenceEngine::new(spec, theta).unwrap()
}

fn input_rows(n: usize, input_len: usize) -> Vec<f32> {
    let mut rng = Rng::new(29);
    let mut x = vec![0f32; n * input_len];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    x
}

fn bench_engine_direct(quick: bool) -> Vec<Json> {
    let engine = bench_engine();
    let d = engine.input_len();
    let total_rows: usize = if quick { 20_000 } else { 200_000 };
    println!("engine-direct: {} (P={})", engine.spec(), engine.n_params());
    println!("{:<8} {:>10} {:>16}", "batch", "passes", "rows/sec");
    let mut rows_json = Vec::new();
    let mut scratch = ForwardScratch::new();
    let mut out = Vec::new();
    for &b in BATCH_SIZES {
        let x = input_rows(b, d);
        let passes = (total_rows / b).max(1);
        // Warmup grows the scratch outside the timing.
        engine.infer_into(&x, b, &mut scratch, &mut out).unwrap();
        let t0 = Instant::now();
        let mut sink = 0f32;
        for _ in 0..passes {
            engine.infer_into(&x, b, &mut scratch, &mut out).unwrap();
            sink += out[0];
        }
        let secs = t0.elapsed().as_secs_f64();
        let rows_per_sec = (passes * b) as f64 / secs;
        println!("{b:<8} {passes:>10} {rows_per_sec:>16.0}   (sink {sink:.3})");
        rows_json.push(json_obj(vec![
            ("batch_rows", Json::Num(b as f64)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
        ]));
    }
    rows_json
}

fn bench_served(quick: bool) -> anyhow::Result<(Vec<Json>, f64)> {
    let engine = bench_engine();
    let d = engine.input_len();
    println!();
    println!("served (loopback TCP): {}", engine.spec());
    println!(
        "{:<8} {:>8} {:>12} {:>14} {:>10} {:>10}",
        "batch", "reqs", "req/s", "rows/sec", "p50 ms", "p99 ms"
    );
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn(move || {
        serve_infer(
            engine,
            listener,
            ServeInferOptions {
                max_sessions: Some(1),
                policy: BatchPolicy {
                    max_batch_rows: 64,
                    // Zero assembly delay: this bench drives ONE
                    // sequential client, so any positive max_delay is a
                    // pure stall floor on every request (nothing else
                    // can arrive) that would inflate the batch-64 /
                    // batch-1 ratio artificially.  With zero delay the
                    // ratio measures exactly what the acceptance bar is
                    // about: wire + dispatch overhead amortizing across
                    // the rows of a request.
                    max_delay: std::time::Duration::ZERO,
                },
                ..Default::default()
            },
        )
        .unwrap()
    });
    let mut client = InferenceClient::connect(&addr)?;
    let total_rows: usize = if quick { 4_000 } else { 40_000 };
    let mut rows_json = Vec::new();
    let mut rows_per_sec_by_batch = Vec::new();
    for &b in BATCH_SIZES {
        let x = input_rows(b, d);
        let reqs = (total_rows / b).max(16);
        // Warmup.
        client.infer(&x, b)?;
        let mut lat_ms = Vec::with_capacity(reqs);
        let mut sink = 0f32;
        let t0 = Instant::now();
        for _ in 0..reqs {
            let tr = Instant::now();
            let (logits, _) = client.infer(&x, b)?;
            lat_ms.push(tr.elapsed().as_secs_f64() * 1e3);
            sink += logits[0];
        }
        let secs = t0.elapsed().as_secs_f64();
        let req_per_sec = reqs as f64 / secs;
        let rows_per_sec = (reqs * b) as f64 / secs;
        let p50 = percentile_ms(&lat_ms, 0.50);
        let p99 = percentile_ms(&lat_ms, 0.99);
        println!(
            "{b:<8} {reqs:>8} {req_per_sec:>12.0} {rows_per_sec:>14.0} {p50:>10.3} \
             {p99:>10.3}   (sink {sink:.3})"
        );
        rows_per_sec_by_batch.push(rows_per_sec);
        rows_json.push(json_obj(vec![
            ("batch_rows", Json::Num(b as f64)),
            ("requests", Json::Num(reqs as f64)),
            ("req_per_sec", Json::Num(req_per_sec)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
            ("p50_ms", Json::Num(p50)),
            ("p99_ms", Json::Num(p99)),
        ]));
    }
    client.close();
    server.join().expect("server thread");
    let speedup = rows_per_sec_by_batch[BATCH_SIZES.len() - 1] / rows_per_sec_by_batch[0];
    println!();
    println!(
        "batch-{} serving delivers {speedup:.2}x the inferences/sec of batch-1 \
         (acceptance bar: >= 4x)",
        BATCH_SIZES[BATCH_SIZES.len() - 1]
    );
    Ok((rows_json, speedup))
}

/// Engine-direct int8 vs f32: rows/sec at batch 64 plus the fidelity
/// numbers (`argmax agreement`, mean |Δlogit|) from the same seeded
/// evaluation set the serve path reports at startup.  The nightly gate
/// reads `int8_over_f32_rows_per_sec` from this record.
fn bench_quantized(quick: bool) -> anyhow::Result<Json> {
    let engine = bench_engine();
    let quant = QuantizedEngine::from_engine(&engine)?;
    let report = quant::fidelity_report(&engine, &quant, 512)?;
    let d = engine.input_len();
    let b = 64usize;
    let total_rows: usize = if quick { 20_000 } else { 200_000 };
    let passes = (total_rows / b).max(1);
    let x = input_rows(b, d);

    let mut scratch = ForwardScratch::new();
    let mut out = Vec::new();
    engine.infer_into(&x, b, &mut scratch, &mut out)?; // scratch warmup
    let mut sink = 0f32;
    let t0 = Instant::now();
    for _ in 0..passes {
        engine.infer_into(&x, b, &mut scratch, &mut out)?;
        sink += out[0];
    }
    let f32_rows_per_sec = (passes * b) as f64 / t0.elapsed().as_secs_f64();

    let mut qscratch = QuantScratch::new();
    quant.infer_into(&x, b, &mut qscratch, &mut out)?;
    let t0 = Instant::now();
    for _ in 0..passes {
        quant.infer_into(&x, b, &mut qscratch, &mut out)?;
        sink += out[0];
    }
    let int8_rows_per_sec = (passes * b) as f64 / t0.elapsed().as_secs_f64();

    let ratio = int8_rows_per_sec / f32_rows_per_sec;
    println!();
    println!("quantized (engine-direct, batch {b}): {}", engine.spec());
    println!(
        "f32 {f32_rows_per_sec:.0} rows/s, int8 {int8_rows_per_sec:.0} rows/s \
         ({ratio:.2}x); agreement {:.4}, mean |dlogit| {:.6} over {} rows   (sink {sink:.3})",
        report.agreement, report.mean_abs_delta, report.rows
    );
    Ok(json_obj(vec![
        ("batch_rows", Json::Num(b as f64)),
        ("f32_rows_per_sec", Json::Num(f32_rows_per_sec)),
        ("int8_rows_per_sec", Json::Num(int8_rows_per_sec)),
        ("int8_over_f32_rows_per_sec", Json::Num(ratio)),
        ("eval_rows", Json::Num(report.rows as f64)),
        ("argmax_agreement", Json::Num(report.agreement)),
        ("mean_abs_logit_delta", Json::Num(report.mean_abs_delta)),
    ]))
}

/// Concurrent-session sweep for the event-loop session layer.
const SESSION_COUNTS: &[usize] = &[1, 8, 64, 256];

/// How many of the sweep's sessions actively send requests; the rest
/// connect and park, costing the server a slab slot instead of a
/// thread.  Throughput staying flat as the idle majority grows is the
/// curve this section exists to record.
const ACTIVE_CAP: usize = 8;

fn bench_sessions(quick: bool) -> anyhow::Result<Vec<Json>> {
    println!();
    println!("sessions (loopback TCP, batch 8, active sessions capped at {ACTIVE_CAP}):");
    println!(
        "{:<10} {:>8} {:>8} {:>12} {:>14}",
        "sessions", "active", "reqs", "req/s", "rows/sec"
    );
    let batch = 8usize;
    let total_reqs: usize = if quick { 1_600 } else { 16_000 };
    let mut rows_json = Vec::new();
    for &n in SESSION_COUNTS {
        let engine = bench_engine();
        let d = engine.input_len();
        let active = n.min(ACTIVE_CAP);
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let server = std::thread::spawn(move || {
            serve_infer(
                engine,
                listener,
                ServeInferOptions {
                    // Idle sessions never send a request frame, so only
                    // the active ones consume the session budget.
                    max_sessions: Some(active),
                    policy: BatchPolicy {
                        max_batch_rows: 64,
                        max_delay: std::time::Duration::ZERO,
                    },
                    ..Default::default()
                },
            )
            .unwrap()
        });
        // Park the idle majority first, so the active traffic below is
        // measured with every one of the n sessions on the loop.
        let parked: Vec<std::net::TcpStream> = (0..n - active)
            .map(|_| std::net::TcpStream::connect(&addr))
            .collect::<std::io::Result<_>>()?;
        let reqs_per_client = (total_reqs / active).max(16);
        let t0 = Instant::now();
        let clients: Vec<_> = (0..active)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || -> anyhow::Result<f32> {
                    let mut client = InferenceClient::connect(&addr)?;
                    let x = input_rows(batch, d);
                    let mut sink = 0f32;
                    for _ in 0..reqs_per_client {
                        let (logits, _) = client.infer(&x, batch)?;
                        sink += logits[0];
                    }
                    client.close();
                    Ok(sink)
                })
            })
            .collect();
        let mut sink = 0f32;
        for client in clients {
            sink += client.join().expect("client thread")?;
        }
        let secs = t0.elapsed().as_secs_f64();
        server.join().expect("server thread");
        drop(parked);
        let reqs = active * reqs_per_client;
        let req_per_sec = reqs as f64 / secs;
        let rows_per_sec = (reqs * batch) as f64 / secs;
        println!(
            "{n:<10} {active:>8} {reqs:>8} {req_per_sec:>12.0} {rows_per_sec:>14.0}   \
             (sink {sink:.3})"
        );
        rows_json.push(json_obj(vec![
            ("sessions", Json::Num(n as f64)),
            ("active", Json::Num(active as f64)),
            ("requests", Json::Num(reqs as f64)),
            ("req_per_sec", Json::Num(req_per_sec)),
            ("rows_per_sec", Json::Num(rows_per_sec)),
        ]));
    }
    Ok(rows_json)
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    if quick {
        println!("infer_throughput (quick mode)");
    }
    let direct = bench_engine_direct(quick);
    let quantized = bench_quantized(quick)?;
    let (served, speedup) = bench_served(quick)?;
    let sessions = bench_sessions(quick)?;
    emit_bench_json(&json_obj(vec![
        ("bench", Json::Str("infer_throughput".into())),
        ("quick", Json::Bool(quick)),
        ("engine_direct", Json::Arr(direct)),
        ("quantized", quantized),
        ("served", Json::Arr(served)),
        ("sessions", Json::Arr(sessions)),
        ("batch64_over_batch1_rows_per_sec", Json::Num(speedup)),
    ]));
    Ok(())
}
