//! Multi-probe cost engine: serial `cost()` vs batched `cost_many()`
//! cost-evaluations/sec, swept over parameter count P.
//!
//! This is the hot path of all of training (ISSUE 2): every MGD timestep
//! is one perturbed cost evaluation, so cost-evals/sec *is* the training
//! speed.  The batched engine amortizes the unperturbed layer-0 walk
//! across the K probes of a parameter-hold window, keeps every buffer in
//! persistent scratch, and fans large sweeps across threads — the serial
//! loop pays the full forward walk per probe.
//!
//! The second section measures the same lever where the paper says it
//! matters most (§6: "the speed will most likely be limited by system
//! I/O"): a `RemoteDevice` over loopback TCP, where `cost_many` ships one
//! `CostMany` frame per K-probe window instead of K `Cost` round trips.
//!
//! ```text
//! cargo bench --bench probe_throughput
//! ```
//!
//! Env toggles (the nightly CI bench job sets both):
//! `MGD_BENCH_QUICK=1` shrinks the sweep; `MGD_BENCH_JSON=path` appends
//! one JSONL record with the per-P batched-vs-serial ratios.

use std::net::TcpListener;
use std::time::Instant;

use mgd::bench::{emit_bench_json, json_obj, quick_mode};
use mgd::device::{server, HardwareDevice, NativeDevice, RemoteDevice};
use mgd::json::Json;
use mgd::optim::init_params_uniform;
use mgd::perturb::{self, Perturbation, PerturbKind};
use mgd::rng::Rng;

/// Probes per cost_many window (a typical τθ integration window).
const K: usize = 64;

/// Build a [98, h, 1] MLP with ≈ `p_target` parameters (P = 100·h + 1).
fn device_with_params(p_target: usize) -> NativeDevice {
    let h = (p_target.saturating_sub(1) / 100).max(1);
    let mut dev = NativeDevice::new(&[98, h, 1], 1);
    let mut rng = Rng::new(7);
    let mut theta = vec![0f32; dev.n_params()];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta).unwrap();
    let mut x = vec![0f32; 98];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    dev.load_batch(&x, &[1.0]).unwrap();
    dev
}

/// One Rademacher probe stack of `k` probes for a P-parameter device.
fn probe_stack(p: usize, k: usize) -> Vec<f32> {
    let mut gen = perturb::make(PerturbKind::RademacherCode, p, 0.01, 1, 11);
    let mut probes = vec![0f32; k * p];
    for i in 0..k {
        gen.fill(i as u64, &mut probes[i * p..(i + 1) * p]);
    }
    probes
}

fn bench_native(quick: bool) -> Vec<Json> {
    println!("native sweep: K = {K} probes/window, batch 1");
    println!(
        "{:<10} {:>8} {:>16} {:>16} {:>9}",
        "P", "windows", "serial ev/s", "batched ev/s", "speedup"
    );
    let p_targets: &[usize] =
        if quick { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let work_budget: usize = if quick { 4_000_000 } else { 20_000_000 };
    let mut rows = Vec::new();
    for &p_target in p_targets {
        let mut dev = device_with_params(p_target);
        let p = dev.n_params();
        let probes = probe_stack(p, K);
        // Keep total work roughly constant across P.
        let windows = (work_budget / (p * K)).clamp(2, 200);

        // Warm up both paths (scratch growth happens here, not in timing).
        let warm = dev.cost_many(&probes, K).unwrap();
        assert_eq!(warm.len(), K);

        let t0 = Instant::now();
        let mut sink = 0f32;
        for _ in 0..windows {
            for i in 0..K {
                sink += dev.cost(Some(&probes[i * p..(i + 1) * p])).unwrap();
            }
        }
        let serial_secs = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for _ in 0..windows {
            let costs = dev.cost_many(&probes, K).unwrap();
            sink += costs[K - 1];
        }
        let batched_secs = t0.elapsed().as_secs_f64();

        let evals = (windows * K) as f64;
        println!(
            "{:<10} {:>8} {:>16.0} {:>16.0} {:>8.2}x   (sink {sink:.3})",
            p,
            windows,
            evals / serial_secs,
            evals / batched_secs,
            serial_secs / batched_secs,
        );
        rows.push(json_obj(vec![
            ("p", Json::Num(p as f64)),
            ("windows", Json::Num(windows as f64)),
            ("serial_evals_per_sec", Json::Num(evals / serial_secs)),
            ("batched_evals_per_sec", Json::Num(evals / batched_secs)),
            ("batched_over_serial", Json::Num(serial_secs / batched_secs)),
        ]));
    }
    rows
}

fn bench_remote(quick: bool) -> anyhow::Result<Json> {
    println!();
    println!("remote loopback: K = {K} probes/window, P ≈ 10k");
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let server = std::thread::spawn(move || {
        let dev: Box<dyn HardwareDevice> = Box::new(device_with_params(10_000));
        server::serve_on(dev, listener, Some(1)).unwrap();
    });
    let mut remote = RemoteDevice::connect(&addr)?;
    let p = remote.n_params();
    let probes = probe_stack(p, K);
    let windows = if quick { 5 } else { 20 };

    let warm = remote.cost_many(&probes, K)?;
    assert_eq!(warm.len(), K);

    let t0 = Instant::now();
    let mut sink = 0f32;
    for _ in 0..windows {
        for i in 0..K {
            sink += remote.cost(Some(&probes[i * p..(i + 1) * p]))?;
        }
    }
    let serial_secs = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    for _ in 0..windows {
        let costs = remote.cost_many(&probes, K)?;
        sink += costs[K - 1];
    }
    let batched_secs = t0.elapsed().as_secs_f64();
    remote.close();
    server.join().expect("server thread");

    let evals = (windows * K) as f64;
    println!(
        "serial : {K} Cost frames/window   {:>12.0} ev/s",
        evals / serial_secs
    );
    println!(
        "batched:  1 CostMany frame/window {:>12.0} ev/s   ({:.2}x, sink {sink:.3})",
        evals / batched_secs,
        serial_secs / batched_secs
    );
    Ok(json_obj(vec![
        ("p", Json::Num(p as f64)),
        ("windows", Json::Num(windows as f64)),
        ("serial_evals_per_sec", Json::Num(evals / serial_secs)),
        ("batched_evals_per_sec", Json::Num(evals / batched_secs)),
        ("batched_over_serial", Json::Num(serial_secs / batched_secs)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    if quick {
        println!("probe_throughput (quick mode)");
    }
    let native = bench_native(quick);
    let remote = bench_remote(quick)?;
    emit_bench_json(&json_obj(vec![
        ("bench", Json::Str("probe_throughput".into())),
        ("quick", Json::Bool(quick)),
        ("probes_per_window", Json::Num(K as f64)),
        ("native", Json::Arr(native)),
        ("remote", remote),
    ]));
    Ok(())
}
