//! Table 3 bench: measured backprop step time vs MGD step time on this
//! testbed, plus the paper's hardware projections.
//!
//! The paper's claim is *not* that MGD beats backprop per-step on a CPU —
//! it's that with realistic hardware time constants (τp down to 200 ps),
//! `steps x τp` beats a GPU's wall-clock.  This bench produces the
//! measured columns; `mgd run table3` combines them with the projections.

use mgd::bench::{fmt_time, Bench};
use mgd::coordinator::{MgdConfig, OnChipTrainer};
use mgd::datasets::{parity, synthetic_cifar, synthetic_fmnist, Dataset};
use mgd::optim::{init_params, BackpropTrainer};
use mgd::rng::Rng;
use mgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(mgd::find_artifact_dir()?)?;
    let b = Bench::quick();
    let rows: [(&str, Dataset, f64); 3] = [
        ("xor221", parity(2), 1e4),
        ("fmnist_cnn", synthetic_fmnist(1024, 42), 1e6),
        ("cifar_cnn", synthetic_cifar(512, 42), 1e7),
    ];
    println!(
        "{:<12} {:>14} {:>14} {:>18} {:>16}",
        "model", "bp step", "mgd step(sim)", "paper steps x HW3", "paper steps x HW1"
    );
    for (model, data, paper_steps) in rows {
        let meta = rt.manifest.model(model)?.clone();
        let mut rng = Rng::new(42);
        let mut theta = vec![0f32; meta.param_count];
        init_params(&mut rng, &meta.tensors, &mut theta);

        // Backprop step (gradtrain artifact).
        let mut bp = BackpropTrainer::new(&rt, model, &data, theta.clone(), 0.1, 42)?;
        bp.step()?; // warm
        let m_bp = b.run(&format!("table3/backprop_step/{model}"), || bp.step().unwrap());

        // MGD step (fused window, amortized).
        let cfg = MgdConfig { eta: 0.05, amplitude: 0.01, seed: 42, ..Default::default() };
        let mut tr = OnChipTrainer::new(&rt, model, &data, theta, cfg)?;
        let m_w = b.run(&format!("table3/mgd_window/{model}"), || tr.window().unwrap()[0]);
        let mgd_step = m_w.median / meta.scan_steps as f64;

        // Paper hardware projections: HW3 τp = 200 ps, HW1 τp = 1 ms.
        let hw3 = paper_steps * 200e-12;
        let hw1 = paper_steps * 1e-3;
        println!(
            "{:<12} {:>14} {:>14} {:>18} {:>16}",
            model,
            fmt_time(m_bp.median),
            fmt_time(mgd_step),
            fmt_time(hw3),
            fmt_time(hw1)
        );
    }
    Ok(())
}
