//! Fig. 4 bench: wall-clock time-to-solution for XOR across training
//! paths (MGD native loop, MGD fused on-chip, backprop-SGD).
//!
//! The end-to-end number behind the figure: how long this testbed takes
//! to actually *solve* the problem, not just run steps.

use std::time::Instant;

use mgd::bench::fmt_time;
use mgd::coordinator::{MgdConfig, MgdTrainer, OnChipTrainer, ScheduleKind, TrainOptions};
use mgd::datasets::parity;
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::metrics::Quartiles;
use mgd::optim::{init_params_uniform, BackpropTrainer};
use mgd::perturb::PerturbKind;
use mgd::rng::Rng;
use mgd::runtime::Runtime;

const SEEDS: [u64; 5] = [0, 1, 2, 3, 5];

fn theta_for(seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut theta = vec![0f32; 9];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    theta
}

fn summarize(name: &str, times: &[f64], solves: usize) {
    match Quartiles::of(times) {
        Some(q) => println!(
            "{:<22} solved {}/{}  median {:>10}  [{} .. {}]",
            name,
            solves,
            SEEDS.len(),
            fmt_time(q.median),
            fmt_time(q.min),
            fmt_time(q.max)
        ),
        None => println!("{name:<22} solved 0/{}", SEEDS.len()),
    }
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(mgd::find_artifact_dir()?)?;
    let data = parity(2);
    let opts = TrainOptions {
        max_steps: 100_000,
        eval_every: 500,
        target_cost: Some(0.04),
        ..Default::default()
    };

    // --- MGD on the native device (hardware-simulator loop) ----------------
    let mut times = Vec::new();
    let mut solves = 0;
    for seed in SEEDS {
        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        dev.set_params(&theta_for(seed))?;
        let cfg = MgdConfig {
            eta: 0.5,
            amplitude: 0.05,
            kind: PerturbKind::RademacherCode,
            seed,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let t0 = Instant::now();
        let res = tr.train(&opts, None)?;
        if res.solved() {
            times.push(t0.elapsed().as_secs_f64());
            solves += 1;
        }
    }
    summarize("mgd/native-loop", &times, solves);

    // --- MGD fused on-chip ---------------------------------------------------
    let mut times = Vec::new();
    let mut solves = 0;
    for seed in SEEDS {
        let cfg = MgdConfig {
            eta: 0.5,
            amplitude: 0.05,
            kind: PerturbKind::RademacherCode,
            seed,
            ..Default::default()
        };
        let mut tr = OnChipTrainer::new(&rt, "xor221", &data, theta_for(seed), cfg)?;
        let t0 = Instant::now();
        let res = tr.train(&opts, &data)?;
        if res.solved() {
            times.push(t0.elapsed().as_secs_f64());
            solves += 1;
        }
    }
    summarize("mgd/onchip-fused", &times, solves);

    // --- Backprop-SGD ---------------------------------------------------------
    let mut times = Vec::new();
    let mut solves = 0;
    for seed in SEEDS {
        let mut tr = BackpropTrainer::new(&rt, "xor221", &data, theta_for(seed), 0.5, seed)?;
        let t0 = Instant::now();
        let res = tr.train(&opts, None)?;
        if res.solved() {
            times.push(t0.elapsed().as_secs_f64());
            solves += 1;
        }
    }
    summarize("backprop/pjrt", &times, solves);
    Ok(())
}
