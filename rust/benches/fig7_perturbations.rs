//! Fig. 7 bench: per-step overhead of each perturbation family plus an
//! RWC-vs-MGD scaling ablation (§3.6's closing argument).
//!
//! Two measurements:
//! 1. generator cost per step at the paper's parameter counts — showing
//!    the coordinator-side multiplexing overhead is negligible against
//!    device inference;
//! 2. steps-to-solve XOR for MGD vs RWC at matched per-step budgets —
//!    the gradient-scaled update (Eq. 4) beats keep/discard at equal
//!    hardware cost.

use mgd::bench::Bench;
use mgd::coordinator::{MgdConfig, MgdTrainer, ScheduleKind, TrainOptions};
use mgd::datasets::parity;
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::metrics::Quartiles;
use mgd::optim::{init_params_uniform, RwcTrainer};
use mgd::perturb::{self, Perturbation, PerturbKind};
use mgd::rng::Rng;

fn main() -> anyhow::Result<()> {
    let b = Bench::default();
    println!("== perturbation-generator overhead ==");
    for p in [9usize, 220, 5130, 26_154] {
        for kind in [
            PerturbKind::RademacherCode,
            PerturbKind::WalshCode,
            PerturbKind::SequentialFd,
            PerturbKind::Sinusoidal,
        ] {
            let mut gen = perturb::make(kind, p, 0.01, 1, 1);
            let mut buf = vec![0f32; p];
            let mut t = 0u64;
            b.run(&format!("fig7/gen/{kind:?}/P={p}"), || {
                gen.fill(t, &mut buf);
                t += 1;
                buf[p - 1]
            });
        }
    }

    println!("\n== MGD vs RWC at matched per-step budget (XOR, 10 seeds) ==");
    let data = parity(2);
    let max_steps = 200_000u64;
    let mut mgd_times = Vec::new();
    let mut rwc_times = Vec::new();
    for seed in 0..10u64 {
        let mut theta = vec![0f32; 9];
        init_params_uniform(&mut Rng::new(seed), &mut theta, 1.0);

        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        dev.set_params(&theta)?;
        let cfg = MgdConfig {
            eta: 0.5,
            amplitude: 0.05,
            kind: PerturbKind::RademacherCode,
            seed,
            ..Default::default()
        };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        let opts = TrainOptions {
            max_steps,
            eval_every: 500,
            target_cost: Some(0.04),
            ..Default::default()
        };
        if let Some(at) = tr.train(&opts, None)?.solved_at {
            mgd_times.push(at as f64);
        }

        let mut dev = NativeDevice::new(&[2, 2, 1], 1);
        dev.set_params(&theta)?;
        let mut tr = RwcTrainer::new(&mut dev, &data, 0.05, 1, seed);
        let opts = TrainOptions {
            max_steps,
            eval_every: 500,
            target_cost: Some(0.04),
            ..Default::default()
        };
        if let Some(at) = tr.train(&opts, None)?.solved_at {
            rwc_times.push(at as f64);
        }
    }
    let report = |name: &str, times: &[f64]| match Quartiles::of(times) {
        Some(q) => println!(
            "{:<6} solved {:>2}/10, median {:>9.0} steps [q1 {:.0}, q3 {:.0}]",
            name,
            times.len(),
            q.median,
            q.q1,
            q.q3
        ),
        None => println!("{name:<6} solved 0/10"),
    };
    report("MGD", &mgd_times);
    report("RWC", &rwc_times);
    Ok(())
}
