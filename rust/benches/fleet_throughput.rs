//! Fleet throughput: jobs/sec and device cost-evals/sec versus pool size.
//!
//! A fixed batch of identical MGD training jobs (XOR, 2'000 steps each) is
//! pushed through fleets of 1, 2, 4 and 8 native devices.  Perfect scaling
//! doubles jobs/sec with the pool; the gap to perfect is the scheduler +
//! lease overhead this bench exists to watch.
//!
//! ```text
//! cargo bench --bench fleet_throughput
//! ```

use std::sync::Arc;
use std::time::Instant;

use mgd::coordinator::{MgdConfig, TrainOptions};
use mgd::datasets::parity;
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::fleet::{Fleet, JobSpec, SchedulerConfig, Telemetry};
use mgd::optim::init_params_uniform;
use mgd::rng::Rng;

const JOBS: usize = 16;
const STEPS: u64 = 2_000;

fn xor_device(seed: u64) -> Box<dyn HardwareDevice> {
    let mut dev = NativeDevice::new(&[2, 2, 1], 1);
    let mut rng = Rng::new(seed);
    let mut theta = vec![0f32; 9];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta).unwrap();
    Box::new(dev)
}

fn main() -> anyhow::Result<()> {
    let data = Arc::new(parity(2));
    println!("fleet_throughput: {JOBS} jobs x {STEPS} MGD steps (XOR, native devices)");
    println!(
        "{:<8} {:>10} {:>12} {:>18} {:>10}",
        "devices", "wall (s)", "jobs/sec", "cost-evals/sec", "speedup"
    );
    let mut baseline = None;
    for &pool_size in &[1usize, 2, 4, 8] {
        let devices: Vec<Box<dyn HardwareDevice>> =
            (0..pool_size).map(|i| xor_device(1000 + i as u64)).collect();
        let fleet = Fleet::new(devices, SchedulerConfig::default(), Telemetry::null());
        let t0 = Instant::now();
        let handles: Vec<_> = (0..JOBS)
            .map(|j| {
                let cfg = MgdConfig {
                    eta: 1.0,
                    amplitude: 0.05,
                    seed: j as u64,
                    ..Default::default()
                };
                let opts = TrainOptions { max_steps: STEPS, ..Default::default() };
                fleet
                    .submit_training(
                        JobSpec::named(format!("xor-{j}")),
                        data.clone(),
                        None,
                        cfg,
                        opts,
                    )
                    .expect("submit")
            })
            .collect();
        let mut total_evals = 0u64;
        for h in handles {
            total_evals += h.wait().expect("job failed").cost_evals;
        }
        let secs = t0.elapsed().as_secs_f64();
        fleet.shutdown()?;
        let jobs_per_sec = JOBS as f64 / secs;
        let speedup = match baseline {
            None => {
                baseline = Some(secs);
                1.0
            }
            Some(b) => b / secs,
        };
        println!(
            "{:<8} {:>10.3} {:>12.2} {:>18.0} {:>9.2}x",
            pool_size,
            secs,
            jobs_per_sec,
            total_evals as f64 / secs,
            speedup
        );
    }
    Ok(())
}
