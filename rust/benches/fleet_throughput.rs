//! Fleet throughput: jobs/sec and device cost-evals/sec versus pool size.
//!
//! A fixed batch of identical MGD training jobs (XOR, 2'000 steps each) is
//! pushed through fleets of 1, 2, 4 and 8 native devices.  Perfect scaling
//! doubles jobs/sec with the pool; the gap to perfect is the scheduler +
//! lease overhead this bench exists to watch.
//!
//! ```text
//! cargo bench --bench fleet_throughput
//! ```
//!
//! Env toggles (the nightly CI bench job sets both):
//! `MGD_BENCH_QUICK=1` shrinks the sweep; `MGD_BENCH_JSON=path` appends
//! one JSONL record with every measured row.

use std::sync::Arc;
use std::time::Instant;

use mgd::bench::{emit_bench_json, json_obj, quick_mode};
use mgd::coordinator::{MgdConfig, TrainOptions};
use mgd::datasets::parity;
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::fleet::{Fleet, JobSpec, SchedulerConfig, Telemetry};
use mgd::json::Json;
use mgd::optim::init_params_uniform;
use mgd::rng::Rng;

fn xor_device(seed: u64) -> Box<dyn HardwareDevice> {
    let mut dev = NativeDevice::new(&[2, 2, 1], 1);
    let mut rng = Rng::new(seed);
    let mut theta = vec![0f32; 9];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta).unwrap();
    Box::new(dev)
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    let jobs: usize = if quick { 8 } else { 16 };
    let steps: u64 = if quick { 500 } else { 2_000 };
    let pool_sizes: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };

    let data = Arc::new(parity(2));
    println!(
        "fleet_throughput: {jobs} jobs x {steps} MGD steps (XOR, native devices{})",
        if quick { ", quick mode" } else { "" }
    );
    println!(
        "{:<8} {:>10} {:>12} {:>18} {:>10}",
        "devices", "wall (s)", "jobs/sec", "cost-evals/sec", "speedup"
    );
    let mut baseline = None;
    let mut rows = Vec::new();
    for &pool_size in pool_sizes {
        let devices: Vec<Box<dyn HardwareDevice>> =
            (0..pool_size).map(|i| xor_device(1000 + i as u64)).collect();
        let fleet = Fleet::new(devices, SchedulerConfig::default(), Telemetry::null());
        let t0 = Instant::now();
        let handles: Vec<_> = (0..jobs)
            .map(|j| {
                let cfg = MgdConfig {
                    eta: 1.0,
                    amplitude: 0.05,
                    seed: j as u64,
                    ..Default::default()
                };
                let opts = TrainOptions { max_steps: steps, ..Default::default() };
                fleet
                    .submit_training(
                        JobSpec::named(format!("xor-{j}")),
                        data.clone(),
                        None,
                        cfg,
                        opts,
                    )
                    .expect("submit")
            })
            .collect();
        let mut total_evals = 0u64;
        for h in handles {
            total_evals += h.wait().expect("job failed").cost_evals;
        }
        let secs = t0.elapsed().as_secs_f64();
        fleet.shutdown()?;
        let jobs_per_sec = jobs as f64 / secs;
        let evals_per_sec = total_evals as f64 / secs;
        let speedup = match baseline {
            None => {
                baseline = Some(secs);
                1.0
            }
            Some(b) => b / secs,
        };
        println!(
            "{:<8} {:>10.3} {:>12.2} {:>18.0} {:>9.2}x",
            pool_size, secs, jobs_per_sec, evals_per_sec, speedup
        );
        rows.push(json_obj(vec![
            ("devices", Json::Num(pool_size as f64)),
            ("wall_secs", Json::Num(secs)),
            ("jobs_per_sec", Json::Num(jobs_per_sec)),
            ("cost_evals_per_sec", Json::Num(evals_per_sec)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    emit_bench_json(&json_obj(vec![
        ("bench", Json::Str("fleet_throughput".into())),
        ("quick", Json::Bool(quick)),
        ("jobs", Json::Num(jobs as f64)),
        ("steps_per_job", Json::Num(steps as f64)),
        ("rows", Json::Arr(rows)),
    ]));
    Ok(())
}
