//! Hot-path micro-benchmarks — the profiling tool for the perf pass
//! (EXPERIMENTS.md §Perf).
//!
//! Measures each layer of the stack in isolation:
//! - L3 substrate ops: perturbation generation per family, homodyne
//!   accumulation, native-device inference;
//! - obs overhead: the full MGD step with the metrics registry gated off
//!   vs on — asserts the always-on instrumentation costs at most 2% of
//!   step throughput (the `mgd::obs` contract), and publishes the ratio
//!   on the bench JSONL stream (`MGD_BENCH_JSON`);
//! - tracing overhead: the same step loop with the span tracer off,
//!   head-sampled at 1/16, and tracing every step — asserts the sampled
//!   setting keeps >= 98% of untraced throughput (the
//!   `mgd::obs::trace` contract) and publishes all three medians;
//! - PJRT boundary: single `cost` artifact call (chip-in-the-loop step
//!   cost), fused `mgd_scan` window (per-step amortized cost), dataset
//!   upload vs resident reuse.  Skipped gracefully when no artifacts are
//!   available, so the L3 + obs sections run everywhere.

use mgd::bench::Bench;
use mgd::coordinator::{MgdConfig, MgdTrainer, OnChipTrainer, ScheduleKind};
use mgd::datasets::{nist7x7, parity};
use mgd::device::exec::{self, KernelMode};
use mgd::device::{HardwareDevice, NativeDevice, PjrtDevice};
use mgd::json::Json;
use mgd::optim::init_params_uniform;
use mgd::perturb::{self, Perturbation, PerturbKind};
use mgd::rng::Rng;
use mgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // Pin the probe sweep to one worker (cached on first read) so the
    // kernel section below is a clean single-thread comparison.  The
    // other sections are P = 220 workloads, under the parallel
    // threshold either way.
    if std::env::var_os("MGD_EXEC_WORKERS").is_none() {
        std::env::set_var("MGD_EXEC_WORKERS", "1");
    }
    let b = Bench::default();
    println!("== L3 substrates ==");

    // Perturbation generation, P = 220 (NIST) and P = 26154 (CIFAR).
    for p in [220usize, 26_154] {
        for kind in [
            PerturbKind::RademacherCode,
            PerturbKind::WalshCode,
            PerturbKind::SequentialFd,
            PerturbKind::Sinusoidal,
        ] {
            let mut gen = perturb::make(kind, p, 0.01, 1, 1);
            let mut buf = vec![0f32; p];
            let mut t = 0u64;
            b.run(&format!("perturb/{kind:?}/P={p}"), || {
                gen.fill(t, &mut buf);
                t += 1;
                buf[0]
            });
        }
    }

    // Homodyne accumulate (pure L3 loop, P = 26154).
    {
        let p = 26_154;
        let mut g = vec![0f32; p];
        let tt = vec![0.01f32; p];
        b.run("homodyne_accumulate/P=26154", || {
            let inv = 1.0 / (0.01f32 * 0.01);
            for (gi, &ti) in g.iter_mut().zip(&tt) {
                *gi += 0.3 * ti * inv;
            }
            g[0]
        });
    }

    // Native device inference (49-4-4, B=1) — the Fig. 8/10 hot loop.
    {
        let mut dev = NativeDevice::new(&[49, 4, 4], 1);
        let mut rng = Rng::new(1);
        let mut theta = vec![0f32; 220];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta)?;
        let data = nist7x7(64, 1);
        let (x, y) = data.gather(&[0]);
        dev.load_batch(&x, &y)?;
        let tt = vec![0.01f32; 220];
        b.run("native_device/cost/nist744", || dev.cost(Some(&tt)).unwrap());
    }

    // Full discrete MGD step on the native device (Algorithm 1 loop body).
    {
        let data = nist7x7(256, 2);
        let mut dev = NativeDevice::new(&[49, 4, 4], 1);
        let mut rng = Rng::new(2);
        let mut theta = vec![0f32; 220];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta)?;
        let cfg = MgdConfig { eta: 0.5, amplitude: 0.01, seed: 2, ..Default::default() };
        let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
        b.run("mgd_step/native/nist744", || tr.step().unwrap().cost);
    }

    println!("\n== exec kernels ==");
    {
        // Scalar vs blocked vs SIMD layer sweeps on one thread
        // (`MGD_EXEC_WORKERS=1` above): probe evaluations per second
        // and approximate GFLOP/s at P = 10k and P = 100k.  Each
        // weight feeds a multiply-add on both the θ and θ̃ paths, so
        // flops ≈ 4 · weights · n · K per `cost_many` call.
        let saved = exec::kernel_mode();
        let sizes: [(&str, &[usize], usize); 2] =
            [("P=10k", &[100, 90, 10], 24), ("P=100k", &[292, 330, 10], 8)];
        for (label, widths, k) in sizes {
            let n = 8usize;
            let mut dev = NativeDevice::new(widths, n);
            let p = dev.n_params();
            let weights: usize = widths.windows(2).map(|w| w[0] * w[1]).sum();
            let mut rng = Rng::new(7);
            let mut theta = vec![0f32; p];
            init_params_uniform(&mut rng, &mut theta, 1.0);
            dev.set_params(&theta)?;
            let mut x = vec![0f32; n * widths[0]];
            let mut y = vec![0f32; n * widths[widths.len() - 1]];
            rng.fill_uniform(&mut x, 0.0, 1.0);
            rng.fill_uniform(&mut y, 0.0, 1.0);
            dev.load_batch(&x, &y)?;
            let mut probes = vec![0f32; k * p];
            rng.fill_uniform(&mut probes, -0.01, 0.01);
            let flops = (4 * weights * n * k) as f64;
            let mut medians = [0f64; 3];
            let modes = [KernelMode::Scalar, KernelMode::Blocked, KernelMode::Simd];
            for (mi, mode) in modes.into_iter().enumerate() {
                exec::set_kernel_mode(mode);
                let m = b.run(&format!("exec_sweep/{label}/{}", mode.as_str()), || {
                    dev.cost_many(&probes, k).unwrap()[0]
                });
                medians[mi] = m.median;
                let evs = k as f64 / m.median;
                let gflops = flops / m.median / 1e9;
                println!("  -> {label} {}: {evs:.0} ev/s, {gflops:.2} GFLOP/s", mode.as_str());
                mgd::bench::emit_bench_json(&mgd::bench::json_obj(vec![
                    ("bench", Json::Str("exec_kernels".into())),
                    ("size", Json::Str(label.into())),
                    ("p", Json::Num(p as f64)),
                    ("mode", Json::Str(mode.as_str().into())),
                    ("median_s", Json::Num(m.median)),
                    ("ev_per_s", Json::Num(evs)),
                    ("gflops", Json::Num(gflops)),
                ]));
            }
            println!(
                "  -> {label}: blocked {:.2}x, simd {:.2}x scalar (single thread)",
                medians[0] / medians[1],
                medians[0] / medians[2]
            );
        }
        exec::set_kernel_mode(saved);
    }

    println!("\n== obs overhead ==");
    {
        // The same trainer loop twice: metrics registry gated off, then
        // on.  The throughput ratio bounds what the always-on
        // instrumentation costs the hottest path (counter/gauge updates
        // in step(), the sweep timer in cost_many, the rows counter).
        let run_steps = |label: &str| -> anyhow::Result<f64> {
            let data = nist7x7(256, 6);
            let mut dev = NativeDevice::new(&[49, 4, 4], 1);
            let mut rng = Rng::new(6);
            let mut theta = vec![0f32; 220];
            init_params_uniform(&mut rng, &mut theta, 1.0);
            dev.set_params(&theta)?;
            let cfg = MgdConfig { eta: 0.5, amplitude: 0.01, seed: 6, ..Default::default() };
            let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            Ok(b.run(label, || tr.step().unwrap().cost).median)
        };
        mgd::obs::set_enabled(false);
        let off = run_steps("mgd_step/obs_off")?;
        mgd::obs::set_enabled(true);
        let on = run_steps("mgd_step/obs_on")?;
        // Instrumented ev/s as a fraction of uninstrumented ev/s.
        let ratio = off / on;
        println!("  -> instrumented throughput is {:.1}% of uninstrumented", ratio * 100.0);
        mgd::bench::emit_bench_json(&mgd::bench::json_obj(vec![
            ("bench", mgd::json::Json::Str("metrics_overhead".into())),
            ("obs_off_median_s", mgd::json::Json::Num(off)),
            ("obs_on_median_s", mgd::json::Json::Num(on)),
            ("throughput_ratio", mgd::json::Json::Num(ratio)),
        ]));
        anyhow::ensure!(
            ratio >= 0.98,
            "metrics overhead exceeds the 2% budget: instrumented throughput is only \
             {:.1}% of uninstrumented",
            ratio * 100.0
        );
    }

    println!("\n== tracing overhead ==");
    {
        // The same loop three more times under the span tracer: off
        // (the production default), head-sampled at 1/16 (the
        // recommended always-on setting), and every-step.  Off must be
        // a branch on one relaxed atomic; sampled must keep >= 98% of
        // untraced step throughput — the `mgd::obs::trace` contract
        // that makes leaving tracing on in production defensible.
        let run_steps = |label: &str| -> anyhow::Result<f64> {
            let data = nist7x7(256, 8);
            let mut dev = NativeDevice::new(&[49, 4, 4], 1);
            let mut rng = Rng::new(8);
            let mut theta = vec![0f32; 220];
            init_params_uniform(&mut rng, &mut theta, 1.0);
            dev.set_params(&theta)?;
            let cfg = MgdConfig { eta: 0.5, amplitude: 0.01, seed: 8, ..Default::default() };
            let mut tr = MgdTrainer::new(&mut dev, &data, cfg, ScheduleKind::Cyclic);
            Ok(b.run(label, || tr.step().unwrap().cost).median)
        };
        mgd::obs::trace::set_sample(0);
        let off = run_steps("mgd_step/trace_off")?;
        mgd::obs::trace::set_sample(16);
        let sampled = run_steps("mgd_step/trace_sampled_16")?;
        mgd::obs::trace::set_sample(1);
        let always = run_steps("mgd_step/trace_always")?;
        mgd::obs::trace::set_sample(0);
        let sampled_ratio = off / sampled;
        let always_ratio = off / always;
        println!(
            "  -> traced throughput: {:.1}% (1/16 sampled), {:.1}% (every step) of untraced",
            sampled_ratio * 100.0,
            always_ratio * 100.0
        );
        mgd::bench::emit_bench_json(&mgd::bench::json_obj(vec![
            ("bench", Json::Str("tracing_overhead".into())),
            ("trace_off_median_s", Json::Num(off)),
            ("trace_sampled_median_s", Json::Num(sampled)),
            ("trace_always_median_s", Json::Num(always)),
            ("sampled_throughput_ratio", Json::Num(sampled_ratio)),
            ("always_throughput_ratio", Json::Num(always_ratio)),
        ]));
        anyhow::ensure!(
            sampled_ratio >= 0.98,
            "tracing overhead exceeds the 2% budget: 1/16-sampled throughput is only \
             {:.1}% of untraced",
            sampled_ratio * 100.0
        );
    }

    println!("\n== PJRT boundary ==");
    let rt = match mgd::find_artifact_dir().and_then(|dir| Runtime::new(&dir)) {
        Ok(rt) => rt,
        Err(e) => {
            println!("(skipping PJRT sections: {e:#})");
            return Ok(());
        }
    };

    // Chip-in-the-loop step: one cost-artifact call (B=1 MLP).
    {
        let mut dev = PjrtDevice::new(&rt, "nist744")?;
        let mut rng = Rng::new(3);
        let mut theta = vec![0f32; 220];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        dev.set_params(&theta)?;
        let data = nist7x7(16, 3);
        let (x, y) = data.gather(&[0]);
        dev.load_batch(&x, &y)?;
        let tt = vec![0.01f32; 220];
        b.run("pjrt_cost_call/nist744", || dev.cost(Some(&tt)).unwrap());
    }

    // Fused scan window (1000 steps/call): amortized per-step cost.
    {
        let data = parity(2);
        let mut rng = Rng::new(4);
        let mut theta = vec![0f32; 9];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        let cfg = MgdConfig { eta: 0.2, amplitude: 0.05, seed: 4, ..Default::default() };
        let mut tr = OnChipTrainer::new(&rt, "xor221", &data, theta, cfg)?;
        let t = tr.window_steps() as f64;
        let m = b.run("mgd_scan_window/xor221(1000 steps)", || tr.window().unwrap()[0]);
        println!(
            "  -> amortized {:.2} us/MGD-step (vs per-call chip-in-the-loop above)",
            m.median * 1e6 / t
        );
    }
    {
        let data = nist7x7(2048, 5);
        let mut rng = Rng::new(5);
        let mut theta = vec![0f32; 220];
        init_params_uniform(&mut rng, &mut theta, 1.0);
        let cfg = MgdConfig { eta: 0.5, amplitude: 0.01, seed: 5, ..Default::default() };
        let mut tr = OnChipTrainer::new(&rt, "nist744", &data, theta, cfg)?;
        let t = tr.window_steps() as f64;
        let m = b.run("mgd_scan_window/nist744(1000 steps)", || tr.window().unwrap()[0]);
        println!("  -> amortized {:.2} us/MGD-step", m.median * 1e6 / t);
    }

    Ok(())
}
