//! Perturbation scaling engine bench: accuracy-vs-cost_evals curves for
//! the dense and structured-sparse/antithetic families at P ∈ {10k, 100k},
//! plus a gradient-estimator variance measurement under cost noise.
//!
//! Two measurements:
//! 1. equal-eval-budget training curves — dense Rademacher, layer-sparse,
//!    block-sparse, and antithetic trainers each get the same number of
//!    device cost evaluations (the paper's hardware-time unit) and report
//!    their (step, cost, accuracy) trajectories;
//! 2. G variance under σ_cost = 1.0 — accumulate G without updates
//!    (τθ = ∞) over equal eval budgets, repeat across seeds, and compare
//!    the per-coordinate variance of the antithetic (central-difference)
//!    estimator against dense forward-difference.
//!
//! The eval-budget arithmetic: at τx = τθ = 20 a forward-difference
//! family spends 21 evals per 20 steps (20 probes + 1 baseline per
//! sample window) while antithetic spends 20 (paired probes, no
//! baseline), so a budget of 420·Q evals runs 400·Q forward-difference
//! steps and 420·Q antithetic steps exactly.
//!
//! ```text
//! cargo bench --bench scaling_variance
//! ```
//!
//! Env toggles (the nightly CI bench job sets both):
//! `MGD_BENCH_QUICK=1` shrinks the budgets; `MGD_BENCH_JSON=path`
//! appends one JSONL record that the workflow merges into
//! `BENCH_scaling.json`.  The nightly job hard-asserts, post-upload:
//! equal `cost_evals` across families at P = 10k,
//! `layer_sparse_over_dense_final_cost <= 1.05`, and
//! `antithetic_over_dense_g_var <= 0.6`.

use mgd::bench::{emit_bench_json, json_obj, quick_mode};
use mgd::coordinator::{MgdConfig, MgdTrainer, ScheduleKind, TrainOptions};
use mgd::datasets::Dataset;
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::json::Json;
use mgd::model::ModelSpec;
use mgd::optim::init_params_uniform;
use mgd::perturb::PerturbKind;
use mgd::rng::Rng;

/// Sample window / update window (even, as antithetic requires).
const TAU: u64 = 20;
/// Evals per 20 steps for a forward-difference family at τx = 20.
const FD_EVALS_PER_TAU: u64 = TAU + 1;

/// P = 100·90+90 + 90·10+10 = 10 000 exactly.
const P10K_SPEC: &str = "100x90x10";
/// P = 300·300+300 + 300·30+30 = 99 330.
const P100K_SPEC: &str = "300x300x30";

/// Argmax-of-a-prefix synthetic task: the label is the index of the
/// largest of the first `n_out` inputs — linearly learnable at any input
/// width, so curves measure the estimator, not the task.
fn argmax_dataset(n_in: usize, n_out: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5343_414c); // "SCAL"
    let mut x = vec![0f32; n * n_in];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let mut y = vec![0f32; n * n_out];
    for i in 0..n {
        let row = &x[i * n_in..i * n_in + n_out];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        y[i * n_out + best] = 1.0;
    }
    Dataset { x, y, n, input_shape: vec![n_in], n_outputs: n_out }
}

fn device_for(spec: &ModelSpec, seed: u64) -> NativeDevice {
    let mut dev = NativeDevice::from_spec(spec.clone(), 1).unwrap();
    let mut rng = Rng::new(seed ^ 0x494e_4954);
    let mut theta = vec![0f32; dev.n_params()];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta).unwrap();
    dev
}

struct FamilyRun {
    label: &'static str,
    cost_evals: u64,
    final_cost: f32,
    final_acc: f32,
    curve: Vec<(u64, f32, f32)>,
}

/// Train one family for `steps` timesteps and return its trajectory.
fn run_family(
    label: &'static str,
    kind: PerturbKind,
    spec: &ModelSpec,
    train_set: &Dataset,
    eval_set: &Dataset,
    steps: u64,
    seed: u64,
) -> anyhow::Result<FamilyRun> {
    let mut dev = device_for(spec, seed);
    let cfg = MgdConfig {
        tau_x: TAU,
        tau_theta: TAU,
        tau_p: 1,
        eta: 0.5,
        amplitude: 0.01,
        kind,
        seed,
        ..Default::default()
    };
    let mut tr = MgdTrainer::try_new(&mut dev, train_set, cfg, ScheduleKind::Cyclic)?;
    let opts = TrainOptions {
        max_steps: steps,
        eval_every: (steps / 8).max(1),
        ..Default::default()
    };
    let res = tr.train(&opts, Some(eval_set))?;
    let (_, final_cost, final_acc) = *res.eval_trace.last().expect("eval trace is non-empty");
    Ok(FamilyRun {
        label,
        cost_evals: res.cost_evals,
        final_cost,
        final_acc,
        curve: res.eval_trace,
    })
}

/// Accumulate G for an equal eval budget with updates disabled and
/// return the per-coordinate G variance across `repeats` seeds, averaged
/// over coordinates.
fn g_variance(
    kind: PerturbKind,
    spec: &ModelSpec,
    train_set: &Dataset,
    steps: u64,
    repeats: u64,
) -> anyhow::Result<(f64, u64)> {
    let mut gs: Vec<Vec<f32>> = Vec::new();
    let mut evals = 0u64;
    for r in 0..repeats {
        // Same θ across repeats: the variance measured is the gradient
        // estimator's, not the landscape's.
        let mut dev = device_for(spec, 7);
        let cfg = MgdConfig {
            tau_x: TAU,
            tau_theta: u64::MAX, // never update: G integrates the whole run
            tau_p: 1,
            eta: 0.5,
            amplitude: 0.01,
            kind,
            noise: mgd::noise::NoiseConfig { sigma_cost: 1.0, sigma_update: 0.0 },
            seed: 0xA0 + r,
            ..Default::default()
        };
        let mut tr = MgdTrainer::try_new(&mut dev, train_set, cfg, ScheduleKind::Cyclic)?;
        for _ in 0..steps {
            tr.step()?;
        }
        evals = tr.cost_evals();
        gs.push(tr.checkpoint()?.g);
    }
    let p = gs[0].len();
    let n = gs.len() as f64;
    let mut var_sum = 0f64;
    for i in 0..p {
        let mean: f64 = gs.iter().map(|g| g[i] as f64).sum::<f64>() / n;
        var_sum += gs.iter().map(|g| (g[i] as f64 - mean).powi(2)).sum::<f64>() / (n - 1.0);
    }
    Ok((var_sum / p as f64, evals))
}

fn curve_json(runs: &[FamilyRun]) -> Json {
    Json::Arr(
        runs.iter()
            .map(|r| {
                json_obj(vec![
                    ("family", Json::Str(r.label.to_string())),
                    ("cost_evals", Json::Num(r.cost_evals as f64)),
                    ("final_cost", Json::Num(r.final_cost as f64)),
                    ("final_accuracy", Json::Num(r.final_acc as f64)),
                    (
                        "curve",
                        Json::Arr(
                            r.curve
                                .iter()
                                .map(|&(s, c, a)| {
                                    Json::Arr(vec![
                                        Json::Num(s as f64),
                                        Json::Num(c as f64),
                                        Json::Num(a as f64),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

fn main() -> anyhow::Result<()> {
    let quick = quick_mode();
    if quick {
        println!("scaling_variance (quick mode)");
    }
    // Q scales the shared eval budget (420·Q evals per family).
    let (q_10k, q_100k, seeds, var_repeats) =
        if quick { (25u64, 5u64, 3u64, 4u64) } else { (250, 25, 3, 8) };

    // -- Section 1: equal-eval-budget curves ------------------------------
    let families: [(&str, PerturbKind); 4] = [
        ("dense", PerturbKind::RademacherCode),
        ("layer_sparse", PerturbKind::LayerSparse),
        ("block_sparse:256", PerturbKind::BlockSparse { block: 256 }),
        ("antithetic", PerturbKind::Antithetic),
    ];
    let mut sections: Vec<(String, Json)> = Vec::new();
    let mut p10k_runs: Vec<FamilyRun> = Vec::new();
    for (spec_str, qq, n_seeds) in [(P10K_SPEC, q_10k, seeds), (P100K_SPEC, q_100k, 1)] {
        let spec: ModelSpec = spec_str.parse()?;
        let p = spec.param_count();
        let train_set = argmax_dataset(spec.n_inputs(), spec.n_outputs(), 256, 1);
        let eval_set = argmax_dataset(spec.n_inputs(), spec.n_outputs(), 256, 2);
        println!(
            "== equal-budget curves: {spec_str} (P = {p}, {} evals/family) ==",
            FD_EVALS_PER_TAU * TAU * qq
        );
        let mut seed_runs: Vec<FamilyRun> = Vec::new();
        for seed in 0..n_seeds {
            for &(label, kind) in &families {
                // Forward-difference families: 400·Q steps = 420·Q evals.
                // Antithetic: 420·Q steps = 420·Q evals (no baseline).
                let steps = if kind == PerturbKind::Antithetic {
                    FD_EVALS_PER_TAU * TAU * qq
                } else {
                    TAU * TAU * qq
                };
                let run =
                    run_family(label, kind, &spec, &train_set, &eval_set, steps, 100 + seed)?;
                println!(
                    "  seed {seed} {label:<18} {:>8} evals  cost {:.5}  acc {:.2}%",
                    run.cost_evals,
                    run.final_cost,
                    run.final_acc * 100.0
                );
                seed_runs.push(run);
            }
        }
        sections.push((format!("p{p}"), curve_json(&seed_runs)));
        if spec_str == P10K_SPEC {
            p10k_runs = seed_runs;
        }
    }

    // Mean final cost per family at P = 10k, across seeds.
    let mean_cost = |label: &str| -> f64 {
        let costs: Vec<f64> = p10k_runs
            .iter()
            .filter(|r| r.label == label)
            .map(|r| r.final_cost as f64)
            .collect();
        costs.iter().sum::<f64>() / costs.len() as f64
    };
    let sparse_over_dense = mean_cost("layer_sparse") / mean_cost("dense");
    let p10k_evals = |label: &str| -> u64 {
        p10k_runs.iter().find(|r| r.label == label).map(|r| r.cost_evals).unwrap_or(0)
    };
    println!(
        "layer_sparse over dense final cost at P=10k: {sparse_over_dense:.4} (bar: <= 1.05)"
    );

    // -- Section 2: G variance under cost noise ---------------------------
    let spec: ModelSpec = P10K_SPEC.parse()?;
    let train_set = argmax_dataset(spec.n_inputs(), spec.n_outputs(), 256, 1);
    println!("== G variance under sigma_cost = 1.0 ({var_repeats} repeats) ==");
    let (dense_var, dense_var_evals) =
        g_variance(PerturbKind::RademacherCode, &spec, &train_set, TAU * TAU, var_repeats)?;
    let anti_steps = FD_EVALS_PER_TAU * TAU;
    let (anti_var, anti_var_evals) =
        g_variance(PerturbKind::Antithetic, &spec, &train_set, anti_steps, var_repeats)?;
    let var_ratio = anti_var / dense_var;
    println!("  dense      var {dense_var:.4e} over {dense_var_evals} evals");
    println!("  antithetic var {anti_var:.4e} over {anti_var_evals} evals");
    println!("  antithetic over dense G variance: {var_ratio:.4} (bar: <= 0.6)");

    let mut record = vec![
        ("bench", Json::Str("scaling_variance".to_string())),
        ("quick", Json::Bool(quick)),
        ("p10k_cost_evals_dense", Json::Num(p10k_evals("dense") as f64)),
        ("p10k_cost_evals_layer_sparse", Json::Num(p10k_evals("layer_sparse") as f64)),
        ("p10k_cost_evals_antithetic", Json::Num(p10k_evals("antithetic") as f64)),
        ("layer_sparse_over_dense_final_cost", Json::Num(sparse_over_dense)),
        ("antithetic_over_dense_g_var", Json::Num(var_ratio)),
        ("g_var_dense", Json::Num(dense_var)),
        ("g_var_antithetic", Json::Num(anti_var)),
        ("g_var_evals", Json::Num(dense_var_evals as f64)),
    ];
    for (name, curves) in &sections {
        record.push((name.as_str(), curves.clone()));
    }
    emit_bench_json(&json_obj(record));
    Ok(())
}
