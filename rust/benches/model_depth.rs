//! `ModelSpec` executor: cost-evaluations/sec vs network depth at fixed
//! P ≈ 10k, serial `cost()` vs batched `cost_many()`.
//!
//! The scaling follow-up (Oripov et al., 2025) puts the interesting
//! perturbative-training regime at growing depth/width; this bench tracks
//! what the generic layer-stack executor pays for depth at constant
//! parameter count — the layer-0 base amortization only covers the first
//! layer, so deeper stacks shift work into the per-probe sweep and the
//! batched-over-serial ratio is the health metric to watch.
//!
//! ```text
//! cargo bench --bench model_depth
//! ```
//!
//! Each depth also re-runs the batched window under the blocked and
//! SIMD kernel modes ([`mgd::device::exec::KernelMode`]) with the sweep
//! pinned to one worker, publishing `simd_over_scalar` per row and the
//! minimum across the sweep — the single-thread speedup bar the nightly
//! workflow hard-asserts after upload.
//!
//! Env toggles (the nightly CI bench job sets both):
//! `MGD_BENCH_QUICK=1` shrinks the sweep; `MGD_BENCH_JSON=path` appends
//! one JSONL record that the workflow merges into `BENCH_model.json`.

use std::time::Instant;

use mgd::bench::{emit_bench_json, json_obj, quick_mode};
use mgd::device::exec::{self, KernelMode};
use mgd::device::{HardwareDevice, NativeDevice};
use mgd::json::Json;
use mgd::model::ModelSpec;
use mgd::optim::init_params_uniform;
use mgd::perturb::{self, PerturbKind, Perturbation};
use mgd::rng::Rng;

/// Probes per cost_many window (a typical τθ integration window).
const K: usize = 64;

/// Depth sweep at P ≈ 10k (exact P printed per row).
const SPECS: &[&str] = &[
    "98x100x1",                        // depth 2 (the legacy shape)
    "98x80x40x1",                      // depth 3
    "98x64x48x32x1",                   // depth 4
    "98x64x48x32x1:relu,relu,tanh,sigmoid", // depth 4, mixed activations
];

fn device_for(spec: &ModelSpec) -> NativeDevice {
    let mut dev = NativeDevice::from_spec(spec.clone(), 1).unwrap();
    let mut rng = Rng::new(7);
    let mut theta = vec![0f32; dev.n_params()];
    init_params_uniform(&mut rng, &mut theta, 1.0);
    dev.set_params(&theta).unwrap();
    let mut x = vec![0f32; spec.n_inputs()];
    rng.fill_uniform(&mut x, 0.0, 1.0);
    let y = vec![1.0f32; spec.n_outputs()];
    dev.load_batch(&x, &y).unwrap();
    dev
}

fn main() -> anyhow::Result<()> {
    // Single-thread comparison: pin the sweep worker count (cached on
    // first read) and start from the scalar reference kernels so the
    // baseline is the pre-library executor regardless of the caller's
    // MGD_EXEC_KERNEL.
    if std::env::var_os("MGD_EXEC_WORKERS").is_none() {
        std::env::set_var("MGD_EXEC_WORKERS", "1");
    }
    exec::set_kernel_mode(KernelMode::Scalar);
    let quick = quick_mode();
    if quick {
        println!("model_depth (quick mode)");
    }
    println!("depth sweep: K = {K} probes/window, P ≈ 10k, batch 1");
    println!(
        "{:<42} {:>6} {:>7} {:>15} {:>15} {:>9}",
        "spec", "P", "windows", "serial ev/s", "batched ev/s", "speedup"
    );
    let work_budget: usize = if quick { 4_000_000 } else { 20_000_000 };
    let mut rows = Vec::new();
    let mut simd_min = f64::INFINITY;
    for s in SPECS {
        let spec: ModelSpec = s.parse().unwrap();
        let mut dev = device_for(&spec);
        let p = dev.n_params();
        let mut gen = perturb::make(PerturbKind::RademacherCode, p, 0.01, 1, 11);
        let mut probes = vec![0f32; K * p];
        for i in 0..K {
            gen.fill(i as u64, &mut probes[i * p..(i + 1) * p]);
        }
        let windows = (work_budget / (p * K)).clamp(2, 200);

        // Warm up both paths (scratch growth happens here, not in timing).
        let warm = dev.cost_many(&probes, K).unwrap();
        assert_eq!(warm.len(), K);

        let t0 = Instant::now();
        let mut sink = 0f32;
        for _ in 0..windows {
            for i in 0..K {
                sink += dev.cost(Some(&probes[i * p..(i + 1) * p])).unwrap();
            }
        }
        let serial_secs = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        for _ in 0..windows {
            let costs = dev.cost_many(&probes, K).unwrap();
            sink += costs[K - 1];
        }
        let batched_secs = t0.elapsed().as_secs_f64();

        // The same batched window under the blocked and SIMD kernels
        // (scalar restored after each): the single-thread speedup rows
        // the nightly gate reads.
        let mut mode_secs = [batched_secs; 3];
        for (mi, mode) in [KernelMode::Blocked, KernelMode::Simd].into_iter().enumerate() {
            exec::set_kernel_mode(mode);
            let warm = dev.cost_many(&probes, K).unwrap(); // blocked-layout scratch growth
            sink += warm[0];
            let t0 = Instant::now();
            for _ in 0..windows {
                let costs = dev.cost_many(&probes, K).unwrap();
                sink += costs[K - 1];
            }
            mode_secs[mi + 1] = t0.elapsed().as_secs_f64();
            exec::set_kernel_mode(KernelMode::Scalar);
        }
        let blocked_over_scalar = mode_secs[0] / mode_secs[1];
        let simd_over_scalar = mode_secs[0] / mode_secs[2];
        simd_min = simd_min.min(simd_over_scalar);

        let evals = (windows * K) as f64;
        println!(
            "{:<42} {:>6} {:>7} {:>15.0} {:>15.0} {:>8.2}x   (sink {sink:.3})",
            s,
            p,
            windows,
            evals / serial_secs,
            evals / batched_secs,
            serial_secs / batched_secs,
        );
        println!(
            "{:<42} kernels: blocked {blocked_over_scalar:.2}x, simd {simd_over_scalar:.2}x \
             scalar (1 thread)",
            ""
        );
        rows.push(json_obj(vec![
            ("spec", Json::Str((*s).into())),
            ("depth", Json::Num(spec.depth() as f64)),
            ("p", Json::Num(p as f64)),
            ("windows", Json::Num(windows as f64)),
            ("serial_evals_per_sec", Json::Num(evals / serial_secs)),
            ("batched_evals_per_sec", Json::Num(evals / batched_secs)),
            ("batched_over_serial", Json::Num(serial_secs / batched_secs)),
            ("simd_evals_per_sec", Json::Num(evals / mode_secs[2])),
            ("blocked_over_scalar", Json::Num(blocked_over_scalar)),
            ("simd_over_scalar", Json::Num(simd_over_scalar)),
        ]));
    }
    emit_bench_json(&json_obj(vec![
        ("bench", Json::Str("model_depth".into())),
        ("quick", Json::Bool(quick)),
        ("probes_per_window", Json::Num(K as f64)),
        ("simd_over_scalar_min", Json::Num(simd_min)),
        ("depths", Json::Arr(rows)),
    ]));
    Ok(())
}
