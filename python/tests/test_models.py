"""L2 correctness: model definitions, parameter layout, artifact heads."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

COMMON = dict(deadline=None, max_examples=15)


# ---------------------------------------------------------------------------
# Parameter counts & layout
# ---------------------------------------------------------------------------


def test_param_counts_match_paper():
    assert M.MODELS["xor221"].param_count == 9
    assert M.MODELS["parity441"].param_count == 25
    assert M.MODELS["nist744"].param_count == 220
    # CIFAR matches the paper's stated count exactly (§3.6).
    assert M.MODELS["cifar_cnn"].param_count == 26154
    # Fashion: paper's description is inconsistent with its stated 14,378;
    # our implementation of the description gives 5,130 (EXPERIMENTS.md).
    assert M.MODELS["fmnist_cnn"].param_count == 5130


def test_tensor_layout_covers_bus_exactly():
    for spec in M.MODELS.values():
        total = sum(t.size for t in spec.tensors())
        assert total == spec.param_count, spec.name


def test_unflatten_roundtrip():
    spec = M.MODELS["nist744"]
    theta = jnp.arange(spec.param_count, dtype=jnp.float32)
    tensors = M.unflatten(spec, theta)
    flat = jnp.concatenate([t.reshape(-1) for t in tensors])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(theta))
    with pytest.raises(ValueError):
        M.unflatten(spec, jnp.zeros(spec.param_count + 1))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 16))
def test_mlp_pallas_equals_ref_path(seed, batch):
    """The Pallas MLP (device path) and the jnp MLP (grad path) must agree."""
    spec = M.MODELS["nist744"]
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    theta = jax.random.normal(ks[0], (spec.param_count,), jnp.float32)
    tt = 0.01 * jax.random.rademacher(ks[1], (spec.param_count,), jnp.float32)
    x = jax.random.uniform(ks[2], (batch, 49), jnp.float32)
    a = M.mlp_forward(spec, theta, x, tt, use_pallas=True)
    b = M.mlp_forward(spec, theta, x, tt, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_mlp_output_shape_and_range():
    spec = M.MODELS["xor221"]
    theta = jnp.zeros(9, jnp.float32)
    x = jnp.array([[0.0, 1.0], [1.0, 1.0]], jnp.float32)
    y = M.mlp_forward(spec, theta, x)
    assert y.shape == (2, 1)
    assert np.all((np.asarray(y) >= 0) & (np.asarray(y) <= 1)), "sigmoid range"


def test_spec_grammar_parses_to_canonical_stems():
    spec = M.parse_spec("784x128x64x10:relu,relu,softmax")
    assert spec.name == "mlp_784x128x64x10_relu-relu-softmax"
    assert spec.layers == (784, 128, 64, 10)
    assert spec.layer_activations == ("relu", "relu", "softmax")
    # No suffix -> all sigmoid; a single activation broadcasts; aliases
    # normalize to the canonical (Rust Activation::as_str) tokens.
    assert M.parse_spec("49x4x4").name == "mlp_49x4x4_sigmoid-sigmoid"
    assert M.parse_spec("8x8x8x2:relu").layer_activations == ("relu",) * 3
    assert M.parse_spec("4x4x1:sig,linear").layer_activations == ("sigmoid", "identity")
    assert M.parse_spec("4x4x1:sig,linear").name == "mlp_4x4x1_sigmoid-identity"
    for bad in ["", "784", "4x0x2", "4xtwox2", "4x4x2:swish", "4x4x2:relu,relu,relu"]:
        with pytest.raises(ValueError):
            M.parse_spec(bad)


@settings(**COMMON)
@given(seed=st.integers(0, 2**31 - 1), batch=st.integers(1, 8))
def test_mixed_activation_pallas_equals_ref_path(seed, batch):
    """Per-layer activations (incl. the outside-the-kernel softmax) agree
    between the Pallas and jnp paths, and softmax rows normalize."""
    spec = M.parse_spec("6x8x5x3:relu,tanh,softmax")
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    theta = jax.random.normal(ks[0], (spec.param_count,), jnp.float32)
    tt = 0.01 * jax.random.rademacher(ks[1], (spec.param_count,), jnp.float32)
    x = jax.random.uniform(ks[2], (batch, 6), jnp.float32)
    a = M.mlp_forward(spec, theta, x, tt, use_pallas=True)
    b = M.mlp_forward(spec, theta, x, tt, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a).sum(axis=-1), np.ones(batch), rtol=1e-5)


@pytest.mark.parametrize("name", ["fmnist_cnn", "cifar_cnn"])
def test_cnn_forward_shapes(name):
    spec = M.MODELS[name]
    key = jax.random.PRNGKey(0)
    theta = 0.1 * jax.random.normal(key, (spec.param_count,), jnp.float32)
    x = jax.random.uniform(key, (3, *spec.input_shape), jnp.float32)
    y = M.cnn_forward(spec, theta, x)
    assert y.shape == (3, spec.n_classes)
    assert np.all(np.isfinite(np.asarray(y)))


def test_cnn_perturbation_rides_on_bus():
    spec = M.MODELS["fmnist_cnn"]
    key = jax.random.PRNGKey(1)
    theta = 0.1 * jax.random.normal(key, (spec.param_count,), jnp.float32)
    tt = 0.05 * jax.random.rademacher(key, (spec.param_count,), jnp.float32)
    x = jax.random.uniform(key, (2, 28, 28, 1), jnp.float32)
    y0 = M.cnn_forward(spec, theta, x)
    y1 = M.cnn_forward(spec, theta, x, tt)
    y2 = M.cnn_forward(spec, theta + tt, x)
    assert not np.allclose(y0, y1), "perturbation had no effect"
    np.testing.assert_allclose(y1, y2, rtol=1e-6)


# ---------------------------------------------------------------------------
# Artifact heads
# ---------------------------------------------------------------------------


def test_cost_fn_baseline_vs_perturbed():
    spec = M.MODELS["xor221"]
    cost_fn = M.make_cost_fn(spec)
    key = jax.random.PRNGKey(2)
    theta = jax.random.normal(key, (9,), jnp.float32)
    zeros = jnp.zeros(9, jnp.float32)
    tt = 0.05 * jax.random.rademacher(key, (9,), jnp.float32)
    x = jnp.array([[1.0, 0.0]], jnp.float32)
    y_hat = jnp.array([[1.0]], jnp.float32)
    (c0,) = cost_fn(theta, zeros, x, y_hat)
    (c1,) = cost_fn(theta, tt, x, y_hat)
    assert c0 >= 0 and c1 >= 0
    assert not np.isclose(float(c0), float(c1)), "perturbation must modulate cost"


def test_eval_fn_counts_correct():
    spec = M.MODELS["nist744"]
    eval_fn = M.make_eval_fn(spec)
    theta = jnp.zeros(spec.param_count, jnp.float32)
    x = jnp.zeros((8, 49), jnp.float32)
    # All-zero θ → uniform outputs → argmax 0 → targets class 0 correct.
    y_hat = jnp.zeros((8, 4), jnp.float32).at[:, 0].set(1.0)
    cost, correct = eval_fn(theta, x, y_hat)
    assert float(correct) == 8.0
    y_hat = jnp.zeros((8, 4), jnp.float32).at[:, 2].set(1.0)
    _, correct = eval_fn(theta, x, y_hat)
    assert float(correct) == 0.0
    assert float(cost) >= 0.0


def test_grad_fn_matches_finite_difference():
    spec = M.MODELS["xor221"]
    grad_fn = M.make_grad_fn(spec)
    key = jax.random.PRNGKey(3)
    theta = jax.random.normal(key, (9,), jnp.float32)
    x = jnp.array([[0.0, 1.0], [1.0, 1.0]], jnp.float32)
    y_hat = jnp.array([[1.0], [0.0]], jnp.float32)
    c, g = grad_fn(theta, x, y_hat)
    eps = 1e-3

    def cost_at(th):
        y = M.forward(spec, th, x, use_pallas=False)
        return float(jnp.mean((y - y_hat) ** 2))

    for i in range(9):
        bump = theta.at[i].add(eps)
        fd = (cost_at(bump) - float(c)) / eps
        assert abs(fd - float(g[i])) < 5e-3, f"param {i}: fd {fd} vs grad {float(g[i])}"


# ---------------------------------------------------------------------------
# Fused MGD scan
# ---------------------------------------------------------------------------


def make_scan(spec, n_steps, use_pallas=True):
    return M.make_mgd_scan_fn(spec, n_steps=n_steps, use_pallas=use_pallas)


def xor_dataset():
    x = jnp.array([[0, 0], [0, 1], [1, 0], [1, 1]], jnp.float32)
    y = jnp.array([[0], [1], [1], [0]], jnp.float32)
    return x, y


def test_mgd_scan_trains_xor():
    spec = M.MODELS["xor221"]
    scan = jax.jit(make_scan(spec, 1000))
    x_all, y_all = xor_dataset()
    idx = (jnp.arange(1000, dtype=jnp.int32) % 4).reshape(1000, 1)
    key = jax.random.PRNGKey(11)
    theta = jax.random.uniform(key, (9,), jnp.float32, -1, 1)
    g = jnp.zeros(9, jnp.float32)
    costs_first = None
    for window in range(20):
        theta, g, costs = scan(
            theta, g, jnp.uint32(window), jnp.float32(0.5), jnp.float32(0.05),
            jnp.float32(0.0), jnp.float32(0.0), jnp.int32(1), jnp.int32(window * 1000),
            x_all, y_all, idx,
        )
        if costs_first is None:
            costs_first = float(jnp.mean(costs))
    final = float(jnp.mean(costs))
    assert final < 0.5 * costs_first, f"no training progress: {costs_first} -> {final}"


def test_mgd_scan_tau_theta_freezes_updates():
    """tau_theta > T: θ must not change inside a window; G must accumulate."""
    spec = M.MODELS["xor221"]
    scan = jax.jit(make_scan(spec, 50))
    x_all, y_all = xor_dataset()
    idx = (jnp.arange(50, dtype=jnp.int32) % 4).reshape(50, 1)
    theta = jax.random.normal(jax.random.PRNGKey(0), (9,), jnp.float32)
    g = jnp.zeros(9, jnp.float32)
    theta2, g2, _ = scan(
        theta, g, jnp.uint32(0), jnp.float32(1.0), jnp.float32(0.05),
        jnp.float32(0.0), jnp.float32(0.0), jnp.int32(10**9), jnp.int32(0),
        x_all, y_all, idx,
    )
    np.testing.assert_array_equal(np.asarray(theta2), np.asarray(theta))
    assert np.any(np.asarray(g2) != 0.0)


def test_mgd_scan_t0_phase_continuity():
    """Running 2x50 steps with correct t0 == the same update cadence as a
    phase-naive run would get wrong (tau_theta = 80 update at step 79)."""
    spec = M.MODELS["xor221"]
    scan = jax.jit(make_scan(spec, 50))
    x_all, y_all = xor_dataset()
    idx = (jnp.arange(50, dtype=jnp.int32) % 4).reshape(50, 1)
    theta0 = jax.random.normal(jax.random.PRNGKey(5), (9,), jnp.float32)
    g0 = jnp.zeros(9, jnp.float32)
    args = lambda t0: (jnp.float32(0.5), jnp.float32(0.05), jnp.float32(0.0),
                       jnp.float32(0.0), jnp.int32(80), jnp.int32(t0), x_all, y_all, idx)
    # Window 1 (steps 0..49): no update (80 ∤ any step+1 in range).
    th1, g1, _ = scan(theta0, g0, jnp.uint32(0), *args(0))
    np.testing.assert_array_equal(np.asarray(th1), np.asarray(theta0))
    # Window 2 (steps 50..99, t0=50): update fires at global step 79.
    th2, g2, _ = scan(th1, g1, jnp.uint32(1), *args(50))
    assert not np.array_equal(np.asarray(th2), np.asarray(theta0)), "t0 phase ignored"
    # With t0 erroneously 0, no update would fire in the second window.
    th2b, _, _ = scan(th1, g1, jnp.uint32(1), *args(0))
    np.testing.assert_array_equal(np.asarray(th2b), np.asarray(th1))


def test_mgd_scan_cost_noise_changes_trajectory():
    spec = M.MODELS["xor221"]
    scan = jax.jit(make_scan(spec, 100))
    x_all, y_all = xor_dataset()
    idx = (jnp.arange(100, dtype=jnp.int32) % 4).reshape(100, 1)
    theta = jax.random.normal(jax.random.PRNGKey(9), (9,), jnp.float32)
    g = jnp.zeros(9, jnp.float32)
    run = lambda sc: scan(theta, g, jnp.uint32(3), jnp.float32(0.5), jnp.float32(0.05),
                          jnp.float32(sc), jnp.float32(0.0), jnp.int32(1), jnp.int32(0),
                          x_all, y_all, idx)
    th_clean, _, costs_clean = run(0.0)
    th_noisy, _, costs_noisy = run(0.5)
    assert not np.allclose(np.asarray(costs_clean), np.asarray(costs_noisy))
    assert not np.allclose(np.asarray(th_clean), np.asarray(th_noisy))


def test_mgd_scan_pallas_and_ref_agree():
    """The fused scan with Pallas kernels inside equals the pure-jnp scan."""
    spec = M.MODELS["xor221"]
    scan_p = jax.jit(make_scan(spec, 64, use_pallas=True))
    scan_r = jax.jit(make_scan(spec, 64, use_pallas=False))
    x_all, y_all = xor_dataset()
    idx = (jnp.arange(64, dtype=jnp.int32) % 4).reshape(64, 1)
    theta = jax.random.normal(jax.random.PRNGKey(13), (9,), jnp.float32)
    g = jnp.zeros(9, jnp.float32)
    args = (theta, g, jnp.uint32(0), jnp.float32(0.5), jnp.float32(0.05),
            jnp.float32(0.0), jnp.float32(0.0), jnp.int32(1), jnp.int32(0),
            x_all, y_all, idx)
    th_p, g_p, c_p = scan_p(*args)
    th_r, g_r, c_r = scan_r(*args)
    np.testing.assert_allclose(th_p, th_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c_p, c_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_p, g_r, rtol=1e-4, atol=1e-4)
