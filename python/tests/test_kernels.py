"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the kernel layer: hypothesis
sweeps shapes (batch, fan-in, fan-out, parameter count), activations and
value ranges, asserting allclose between ``pl.pallas_call`` (interpret
mode) and ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, homodyne, ref

# Keep example counts moderate: every example traces a pallas_call.
COMMON = dict(deadline=None, max_examples=25)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# dense_forward
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    batch=st.integers(1, 40),
    n_in=st.integers(1, 64),
    n_out=st.integers(1, 24),
    activation=st.sampled_from(["sigmoid", "relu", "linear"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_oracle(batch, n_in, n_out, activation, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = rand(ks[0], (batch, n_in))
    w = rand(ks[1], (n_in, n_out))
    b = rand(ks[2], (n_out,))
    wt = rand(ks[3], (n_in, n_out), scale=0.01)
    bt = rand(ks[4], (n_out,), scale=0.01)
    got = dense.dense_forward(x, w, b, wt, bt, activation)
    want = ref.dense_forward_ref(x, w, b, wt, bt, activation)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_dense_zero_perturbation_is_baseline():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = rand(ks[0], (8, 16))
    w = rand(ks[1], (16, 4))
    b = rand(ks[2], (4,))
    z = jnp.zeros_like(w)
    zb = jnp.zeros_like(b)
    base = dense.dense_forward(x, w, b, z, zb, "sigmoid")
    want = ref.activate(x @ w + b, "sigmoid")
    np.testing.assert_allclose(base, want, rtol=1e-5, atol=1e-6)


def test_dense_shape_validation():
    x = jnp.zeros((2, 3))
    w = jnp.zeros((4, 5))  # contraction mismatch
    b = jnp.zeros((5,))
    with pytest.raises(ValueError):
        dense.dense_forward(x, w, b, jnp.zeros_like(w), b)
    w = jnp.zeros((3, 5))
    with pytest.raises(ValueError):
        dense.dense_forward(x, w, jnp.zeros((4,)), jnp.zeros_like(w), jnp.zeros((4,)))
    with pytest.raises(ValueError):
        dense.dense_forward(x, w, b, jnp.zeros((3, 4)), b)


def test_dense_is_jittable_and_aot_stable():
    """The kernel must trace under jit (the AOT path) bit-identically."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    args = (
        rand(ks[0], (4, 7)),
        rand(ks[1], (7, 3)),
        rand(ks[2], (3,)),
        rand(ks[3], (7, 3), 0.01),
        rand(ks[4], (3,), 0.01),
    )
    eager = dense.dense_forward(*args, "relu")
    jitted = jax.jit(lambda *a: dense.dense_forward(*a, "relu"))(*args)
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_dense_vmem_footprint_fits_budget():
    """DESIGN.md §Perf: the largest model tile must fit TPU VMEM (~16 MiB)."""
    for batch, n_in, n_out in [(1, 2, 2), (1, 49, 4), (100, 256, 10), (512, 49, 4)]:
        assert dense.vmem_footprint_bytes(batch, n_in, n_out) < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# homodyne_accumulate
# ---------------------------------------------------------------------------


@settings(**COMMON)
@given(
    p=st.integers(1, 2048),
    c_tilde=st.floats(-5.0, 5.0, allow_nan=False, width=32),
    dtheta=st.floats(0.0009765625, 1.0, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_homodyne_matches_oracle(p, c_tilde, dtheta, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    g = rand(ks[0], (p,))
    tt = dtheta * jax.random.rademacher(ks[1], (p,), jnp.float32)
    got = homodyne.homodyne_accumulate(g, c_tilde, tt, dtheta)
    want = ref.homodyne_accumulate_ref(g, c_tilde, tt, dtheta)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_homodyne_zero_modulation_is_identity():
    g = jnp.arange(100, dtype=jnp.float32)
    tt = jnp.ones(100, jnp.float32)
    out = homodyne.homodyne_accumulate(g, 0.0, tt, 0.01)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_homodyne_accumulates_additively():
    """Two accumulations == one accumulation of the summed error signal."""
    key = jax.random.PRNGKey(3)
    g0 = jnp.zeros(64, jnp.float32)
    tt = 0.05 * jax.random.rademacher(key, (64,), jnp.float32)
    g1 = homodyne.homodyne_accumulate(g0, 0.3, tt, 0.05)
    g2 = homodyne.homodyne_accumulate(g1, -0.1, tt, 0.05)
    want = ref.homodyne_accumulate_ref(
        ref.homodyne_accumulate_ref(g0, 0.3, tt, 0.05), -0.1, tt, 0.05
    )
    np.testing.assert_allclose(g2, want, rtol=1e-5, atol=1e-6)


def test_homodyne_gradient_direction_on_quadratic():
    """End-to-end Eq. 3 check: homodyne-estimated gradient of a quadratic
    cost aligns with the analytic gradient."""
    p = 32
    key = jax.random.PRNGKey(7)
    theta = jax.random.normal(key, (p,), jnp.float32)
    true_grad = 2.0 * theta  # C = |theta|^2
    dtheta = 1e-3
    g = jnp.zeros(p, jnp.float32)
    for t in range(400):
        kt = jax.random.fold_in(key, t)
        tt = dtheta * jax.random.rademacher(kt, (p,), jnp.float32)
        c0 = jnp.sum(theta * theta)
        c = jnp.sum((theta + tt) ** 2)
        g = homodyne.homodyne_accumulate(g, c - c0, tt, dtheta)
    g = np.asarray(g) / 400.0
    cos = np.dot(g, true_grad) / (np.linalg.norm(g) * np.linalg.norm(true_grad))
    assert cos > 0.95, f"homodyne estimate misaligned: cos={cos}"
