"""AOT pipeline: lowering, manifest integrity, HLO-text compatibility.

These tests exercise ``compile.aot`` end-to-end into a temp directory and
validate the manifest contract the Rust runtime depends on.
"""

import json
import os

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.build(str(out), ["xor221"], None)
    return out


def test_manifest_schema(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["format"] == 1
    assert "xor221" in manifest["models"]
    model = manifest["models"]["xor221"]
    assert model["param_count"] == 9
    assert model["input_shape"] == [2]
    assert [t["name"] for t in model["tensors"]] == ["w0", "b0", "w1", "b1"]
    names = {a["name"] for a in manifest["artifacts"]}
    assert names == {
        "xor221_cost", "xor221_eval", "xor221_grad", "xor221_gradtrain", "xor221_mgd_scan",
    }
    for art in manifest["artifacts"]:
        assert os.path.exists(built / art["file"]), art["file"]
        assert art["inputs"], art["name"]
        assert art["outputs"], art["name"]


def test_hlo_text_is_parseable_entry_module(built):
    """The interchange contract: HLO *text* with an ENTRY computation and
    no Mosaic custom-calls (interpret-mode Pallas only)."""
    for name in ["xor221_cost", "xor221_mgd_scan"]:
        text = (built / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        assert "mosaic" not in text.lower(), f"{name}: TPU custom-call leaked into CPU artifact"


def test_scan_artifact_signature(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    scan = next(a for a in manifest["artifacts"] if a["name"] == "xor221_mgd_scan")
    names = [i["name"] for i in scan["inputs"]]
    assert names == [
        "theta", "g", "seed", "eta", "dtheta", "sigma_c", "sigma_th",
        "tau_theta", "t0", "x_all", "y_all", "idx",
    ]
    dtypes = {i["name"]: i["dtype"] for i in scan["inputs"]}
    assert dtypes["seed"] == "u32"
    assert dtypes["tau_theta"] == "i32"
    assert dtypes["idx"] == "i32"
    # Outputs: theta', g', costs[T]
    assert [o["shape"] for o in scan["outputs"]] == [[9], [9], [1000]]


def test_incremental_rebuild_preserves_other_models(built):
    """Partial builds must merge with the existing manifest."""
    aot.build(str(built), ["parity441"], kinds=["cost"])
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    names = {a["name"] for a in manifest["artifacts"]}
    assert "xor221_cost" in names, "previous artifacts lost"
    assert "parity441_cost" in names
    assert "parity441" in manifest["models"]


def test_sha256_matches_file(built):
    import hashlib

    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    art = next(a for a in manifest["artifacts"] if a["name"] == "xor221_cost")
    text = (built / art["file"]).read_text()
    assert hashlib.sha256(text.encode()).hexdigest() == art["sha256"]


def test_spec_model_builds_under_the_canonical_stem(built):
    """A grammar spec compiles to artifacts named by its canonical
    ``mlp_<widths>_<acts>`` stem — exactly what ``PjrtDevice::for_spec``
    looks up — with the per-layer activation list in the manifest."""
    aot.build(str(built), ["4x3x2:relu,softmax"], kinds=["cost", "eval"])
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    stem = "mlp_4x3x2_relu-softmax"
    assert stem in manifest["models"]
    model = manifest["models"][stem]
    assert model["layers"] == [4, 3, 2]
    assert model["activation"] == "relu,softmax"
    assert model["param_count"] == 4 * 3 + 3 + 3 * 2 + 2
    names = {a["name"] for a in manifest["artifacts"]}
    assert f"{stem}_cost" in names
    assert f"{stem}_eval" in names
    for art in manifest["artifacts"]:
        if art["model"] == stem:
            assert os.path.exists(built / art["file"]), art["file"]
    # Uniform stacks keep the legacy single-token activation form.
    aot.build(str(built), ["3x3x1:relu"], kinds=["cost"])
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["models"]["mlp_3x3x1_relu-relu"]["activation"] == "relu"


def test_resolve_model_accepts_ids_and_specs():
    assert aot.resolve_model("xor221") is M.MODELS["xor221"]
    spec = aot.resolve_model("49x4x4:relu,relu")
    assert spec.name == "mlp_49x4x4_relu-relu"
    assert aot.dims_for(spec) == aot.DEFAULT_SPEC_DIMS
    assert aot.dims_for(M.MODELS["xor221"]) == aot.ARTIFACT_DIMS["xor221"]
    with pytest.raises(ValueError):
        aot.resolve_model("not-a-model")


def test_artifact_dims_consistent_with_models():
    for name, (b_cost, b_eval, b_train, scan) in aot.ARTIFACT_DIMS.items():
        spec = M.MODELS[name]
        assert b_cost >= 1 and b_eval >= 1 and b_train >= 1
        assert scan.dataset_n >= scan.batch
        specs = aot.artifact_specs(spec)
        assert set(specs) == {"cost", "eval", "grad", "gradtrain", "mgd_scan"}
        # Every input spec must carry a manifest-compatible dtype.
        for _, (fn, inputs) in specs.items():
            for (_, _, dt) in inputs:
                assert dt in aot._DTYPES
