"""AOT pipeline: lower every artifact to HLO text + write the manifest.

This is the only place Python touches the build.  ``make artifacts`` runs
``python -m compile.aot --out-dir ../artifacts`` once; afterwards the Rust
binary is self-contained: it loads ``artifacts/manifest.json``, compiles
each ``*.hlo.txt`` on the PJRT CPU client, and never imports Python again.

Interchange format is **HLO text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and its README).

Artifact kinds per model (see ``model.py`` for the function bodies):

- ``cost``      device-side perturbed cost (chip-in-the-loop hot path)
- ``eval``      cost + correct-count over an eval batch
- ``grad``      value+grad over the eval batch (Fig. 5 angle / full-batch BP)
- ``gradtrain`` value+grad over the training batch (backprop-SGD baseline)
- ``mgd_scan``  fused on-chip MGD window: T complete timesteps per call

The manifest records every input/output name, dtype and shape, plus the
model's parameter layout (tensor names/shapes/init schemes) so the Rust
side can initialize and address the flat parameter bus byte-compatibly.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Callable

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# ---------------------------------------------------------------------------
# Artifact table
# ---------------------------------------------------------------------------
#
# Dataset sizes are static dims of the mgd_scan artifacts (the dataset is a
# resident device buffer on the Rust side).  Train-set sizes follow the
# paper where feasible (NIST7x7: 44,136 examples) and are scaled for the
# CPU testbed otherwise (synthetic Fashion/CIFAR: 8,192/4,096 — documented
# in DESIGN.md §3).


@dataclasses.dataclass(frozen=True)
class ScanDims:
    """Static dimensions of a fused mgd_scan artifact."""

    n_steps: int      # T: timesteps per PJRT call
    batch: int        # B: samples shown per timestep
    dataset_n: int    # N: resident dataset rows


# model id -> (cost batch, eval batch, gradtrain batch, scan dims)
ARTIFACT_DIMS: dict[str, tuple[int, int, int, ScanDims]] = {
    "xor221": (1, 4, 1, ScanDims(n_steps=1000, batch=1, dataset_n=4)),
    "parity441": (1, 16, 1, ScanDims(n_steps=1000, batch=1, dataset_n=16)),
    "nist744": (1, 512, 1, ScanDims(n_steps=1000, batch=1, dataset_n=44136)),
    "fmnist_cnn": (100, 256, 100, ScanDims(n_steps=50, batch=100, dataset_n=8192)),
    "cifar_cnn": (100, 256, 100, ScanDims(n_steps=50, batch=100, dataset_n=4096)),
}

# Grammar-spec models (arbitrary ``784x128x64x10:relu,relu,softmax``
# stacks) have no per-model dim table; they get the chip-in-the-loop
# defaults (cost batch 1 = one sample at a time, a 256-row eval batch,
# and a generic resident-dataset scan window).
DEFAULT_SPEC_DIMS: tuple[int, int, int, ScanDims] = (
    1,
    256,
    1,
    ScanDims(n_steps=1000, batch=1, dataset_n=2048),
)


def dims_for(spec: M.MlpSpec | M.CnnSpec) -> tuple[int, int, int, ScanDims]:
    """Artifact dims for a model: the curated table for the paper's
    models, :data:`DEFAULT_SPEC_DIMS` for grammar-spec stacks."""
    return ARTIFACT_DIMS.get(spec.name, DEFAULT_SPEC_DIMS)


def resolve_model(name: str) -> M.MlpSpec | M.CnnSpec:
    """A build target: a curated model id, or a spec-grammar string that
    registers under its canonical ``mlp_<widths>_<acts>`` stem — the
    name ``PjrtDevice::for_spec`` falls back to, so any ``--model`` spec
    the Rust CLI accepts can be compiled here verbatim."""
    if name in M.MODELS:
        return M.MODELS[name]
    try:
        return M.parse_spec(name)
    except ValueError as e:
        raise ValueError(
            f"unknown model {name!r}: not a curated id ({list(M.MODELS)}) and not a "
            f"model spec ({e})"
        ) from None

F32 = jnp.float32


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text via stablehlo.

    ``return_tuple=True`` so every artifact's outputs arrive as one tuple
    literal on the Rust side (unpacked with ``decompose_tuple``).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# Per-artifact input specs
# ---------------------------------------------------------------------------


def artifact_specs(spec: M.MlpSpec | M.CnnSpec) -> dict[str, tuple[Callable, list[tuple[str, tuple, str]]]]:
    """Return ``kind -> (fn, [(input_name, shape, dtype_str), ...])``."""
    p = spec.param_count
    in_shape = spec.input_shape
    k = spec.n_outputs
    b_cost, b_eval, b_train, scan = dims_for(spec)

    def xin(b):
        return (b, *in_shape)

    specs: dict[str, tuple[Callable, list[tuple[str, tuple, str]]]] = {
        "cost": (
            M.make_cost_fn(spec),
            [
                ("theta", (p,), "f32"),
                ("theta_tilde", (p,), "f32"),
                ("x", xin(b_cost), "f32"),
                ("y_hat", (b_cost, k), "f32"),
            ],
        ),
        "eval": (
            M.make_eval_fn(spec),
            [
                ("theta", (p,), "f32"),
                ("x", xin(b_eval), "f32"),
                ("y_hat", (b_eval, k), "f32"),
            ],
        ),
        "grad": (
            M.make_grad_fn(spec),
            [
                ("theta", (p,), "f32"),
                ("x", xin(b_eval), "f32"),
                ("y_hat", (b_eval, k), "f32"),
            ],
        ),
        "gradtrain": (
            M.make_grad_fn(spec),
            [
                ("theta", (p,), "f32"),
                ("x", xin(b_train), "f32"),
                ("y_hat", (b_train, k), "f32"),
            ],
        ),
        "mgd_scan": (
            M.make_mgd_scan_fn(spec, n_steps=scan.n_steps),
            [
                ("theta", (p,), "f32"),
                ("g", (p,), "f32"),
                ("seed", (), "u32"),
                ("eta", (), "f32"),
                ("dtheta", (), "f32"),
                ("sigma_c", (), "f32"),
                ("sigma_th", (), "f32"),
                ("tau_theta", (), "i32"),
                ("t0", (), "i32"),
                ("x_all", (scan.dataset_n, *in_shape), "f32"),
                ("y_all", (scan.dataset_n, k), "f32"),
                ("idx", (scan.n_steps, scan.batch), "i32"),
            ],
        ),
    }
    return specs


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}


def lower_artifact(fn: Callable, inputs: list[tuple[str, tuple, str]]) -> tuple[str, list[dict]]:
    """Jit + lower ``fn`` at the given input shapes; return HLO text + output metadata."""
    args = [_sds(shape, _DTYPES[dt]) for (_, shape, dt) in inputs]
    lowered = jax.jit(fn).lower(*args)
    # Output metadata from the jax lowering itself (authoritative).
    out_info = lowered.out_info
    leaves = jax.tree_util.tree_leaves(out_info)
    outputs = [
        {"shape": list(leaf.shape), "dtype": jnp.dtype(leaf.dtype).name} for leaf in leaves
    ]
    return to_hlo_text(lowered), outputs


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------


def model_manifest_entry(spec: M.MlpSpec | M.CnnSpec) -> dict:
    """Everything Rust needs to own the parameter bus for this model."""
    b_cost, b_eval, b_train, scan = dims_for(spec)
    entry = {
        "param_count": spec.param_count,
        "input_shape": list(spec.input_shape),
        "n_outputs": spec.n_outputs,
        "kind": "mlp" if isinstance(spec, M.MlpSpec) else "cnn",
        "batch_cost": b_cost,
        "batch_eval": b_eval,
        "batch_train": b_train,
        "scan_steps": scan.n_steps,
        "scan_batch": scan.batch,
        "scan_dataset_n": scan.dataset_n,
        "tensors": [
            {"name": t.name, "shape": list(t.shape), "init": t.init}
            for t in spec.tensors()
        ],
    }
    if isinstance(spec, M.MlpSpec):
        entry["layers"] = list(spec.layers)
        # Uniform stacks keep the legacy single-token form; mixed stacks
        # write the full per-layer comma list (the Rust manifest reader
        # parses both into the same typed ModelSpec).
        acts = spec.layer_activations
        entry["activation"] = acts[0] if len(set(acts)) == 1 else ",".join(acts)
    return entry


def build(out_dir: str, models: list[str], kinds: list[str] | None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"format": 1, "models": {}, "artifacts": []}
    # Merge with an existing manifest so partial builds keep older entries.
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            manifest["models"].update(old.get("models", {}))
            manifest["artifacts"] = [
                a
                for a in old.get("artifacts", [])
                if os.path.exists(os.path.join(out_dir, a["file"]))
            ]
        except (json.JSONDecodeError, KeyError):
            pass

    existing = {a["name"]: a for a in manifest["artifacts"]}
    for name in models:
        # Grammar specs register under their canonical stem, so
        # `spec.name` (not the raw argument) keys everything below.
        spec = resolve_model(name)
        name = spec.name
        manifest["models"][name] = model_manifest_entry(spec)
        for kind, (fn, inputs) in artifact_specs(spec).items():
            if kinds and kind not in kinds:
                continue
            art_name = f"{name}_{kind}"
            print(f"[aot] lowering {art_name} ...", flush=True)
            hlo, outputs = lower_artifact(fn, inputs)
            fname = f"{art_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            existing[art_name] = {
                "name": art_name,
                "model": name,
                "kind": kind,
                "file": fname,
                "sha256": hashlib.sha256(hlo.encode()).hexdigest(),
                "inputs": [
                    {"name": n, "shape": list(s), "dtype": d} for (n, s, d) in inputs
                ],
                "outputs": outputs,
            }
            print(f"[aot]   wrote {fname} ({len(hlo)} chars)", flush=True)

    manifest["artifacts"] = sorted(existing.values(), key=lambda a: a["name"])
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest: {manifest_path} ({len(manifest['artifacts'])} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    ap.add_argument(
        "--models",
        default=",".join(M.MODELS),
        help=(
            f"comma-separated mix of curated ids ({','.join(M.MODELS)}) and/or "
            "model specs like 784x128x64x10:relu;relu;softmax — spec activations "
            "may be separated with ';' here (',' splits the model list) and "
            "artifacts land under the canonical mlp_<widths>_<acts> stem"
        ),
    )
    ap.add_argument(
        "--kinds",
        default="",
        help="comma-separated subset of artifact kinds (default: all)",
    )
    args = ap.parse_args()
    # ',' splits the model list, so spec activations use ';' on the CLI
    # (`49x4x4:relu;relu`); normalize to the grammar's ',' per item.
    models = [m.strip().replace(";", ",") for m in args.models.split(",") if m.strip()]
    for m in models:
        try:
            resolve_model(m)
        except ValueError as e:
            raise SystemExit(str(e)) from None
    kinds = [k.strip() for k in args.kinds.split(",") if k.strip()] or None
    build(args.out_dir, models, kinds)


if __name__ == "__main__":
    main()
