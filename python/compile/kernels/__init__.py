"""L1 Pallas kernels for the MGD hot path.

- ``dense``:    perturbed dense-layer forward (MXU-tiled matmul).
- ``homodyne``: per-parameter homodyne gradient accumulation (VPU FMA).
- ``ref``:      pure-jnp oracles used by pytest as ground truth.
"""

from . import dense, homodyne, ref  # noqa: F401
