"""L1 Pallas kernel: perturbed dense layer forward pass.

Computes ``act(x @ (w + w_tilde) + (b + b_tilde))`` as a tiled Pallas
kernel.  This is the inference hot-spot of every MLP in the paper (XOR
2-2-1, parity n-n-1, NIST7x7 49-4-4): during MGD training the device
evaluates this layer twice per timestep (baseline cost C0 and perturbed
cost C), so it dominates the device-side FLOPs.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the output
``[B, M]`` plane; each program instance holds an ``[bb, N]`` slab of
activations and an ``[N, bm]`` slab of fused weights ``w + w_tilde`` in
VMEM and drives a single MXU matmul.  The perturbation add is a VPU
elementwise op fused into the same VMEM residency — the paper's "perturb
a separate element in series with the parameter" (§4.1) becomes a fused
add on the weight tile rather than a separate memory.

CPU/AOT note: the kernel is lowered with ``interpret=True`` so that the
resulting HLO contains only portable ops that the PJRT CPU client can
execute (real TPU lowering emits a Mosaic custom-call).  The block
structure is preserved either way, so the artifact is layout-portable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Target tile edges.  The MXU systolic array is 128x128; we clamp to the
# actual dimension and then shrink to the largest divisor so that the
# grid covers the array exactly (no masked tail iterations — interpret
# mode has no implicit out-of-bounds masking for stores).
_TARGET_BLOCK_B = 128
_TARGET_BLOCK_M = 128


def _largest_divisor_at_most(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (always >= 1)."""
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def _dense_kernel(x_ref, w_ref, b_ref, wt_ref, bt_ref, o_ref, *, activation: str):
    """Pallas kernel body for one ``[bb, bm]`` output tile.

    ``x_ref``: [bb, N] activation slab, ``w_ref``/``wt_ref``: [N, bm]
    weight + perturbation slabs, ``b_ref``/``bt_ref``: [bm] bias slabs.
    """
    w_eff = w_ref[...] + wt_ref[...]           # VPU add, fused in VMEM
    b_eff = b_ref[...] + bt_ref[...]
    z = jnp.dot(x_ref[...], w_eff, preferred_element_type=jnp.float32)
    z = z + b_eff[None, :]
    o_ref[...] = ref.activate(z, activation)


def dense_forward(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    w_tilde: jnp.ndarray,
    b_tilde: jnp.ndarray,
    activation: str = "sigmoid",
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Perturbed dense layer via ``pl.pallas_call``.

    Semantics identical to :func:`compile.kernels.ref.dense_forward_ref`;
    see that docstring for argument shapes.  ``interpret=True`` (the
    default) keeps the lowered HLO runnable on the CPU PJRT client.
    """
    batch, n_in = x.shape
    n_in_w, n_out = w.shape
    if n_in != n_in_w:
        raise ValueError(f"x/w contraction mismatch: {x.shape} vs {w.shape}")
    if b.shape != (n_out,) or b_tilde.shape != (n_out,):
        raise ValueError(f"bias shape mismatch: {b.shape} vs ({n_out},)")
    if w_tilde.shape != w.shape:
        raise ValueError(f"w_tilde shape mismatch: {w_tilde.shape} vs {w.shape}")

    bb = _largest_divisor_at_most(batch, _TARGET_BLOCK_B)
    bm = _largest_divisor_at_most(n_out, _TARGET_BLOCK_M)
    grid = (batch // bb, n_out // bm)

    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_in), lambda i, j: (i, 0)),     # x slab
            pl.BlockSpec((n_in, bm), lambda i, j: (0, j)),     # w slab
            pl.BlockSpec((bm,), lambda i, j: (j,)),            # b slab
            pl.BlockSpec((n_in, bm), lambda i, j: (0, j)),     # w_tilde slab
            pl.BlockSpec((bm,), lambda i, j: (j,)),            # b_tilde slab
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((batch, n_out), jnp.float32),
        interpret=interpret,
    )(x, w, b, w_tilde, b_tilde)


def vmem_footprint_bytes(batch: int, n_in: int, n_out: int) -> int:
    """Estimated per-instance VMEM footprint of the kernel in bytes.

    Used by DESIGN.md §Perf to check the tiling against the ~16 MiB VMEM
    budget of a TPU core: x slab + 2 weight slabs + 2 bias slabs + output
    tile, all f32.
    """
    bb = _largest_divisor_at_most(batch, _TARGET_BLOCK_B)
    bm = _largest_divisor_at_most(n_out, _TARGET_BLOCK_M)
    floats = bb * n_in + 2 * n_in * bm + 2 * bm + bb * bm
    return 4 * floats
