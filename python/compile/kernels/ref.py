"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground-truth implementations that the Pallas kernels in
``dense.py`` and ``homodyne.py`` are checked against by
``python/tests/test_kernels.py`` (hypothesis sweeps over shapes/dtypes,
``assert_allclose``).  They are also reused by ``model.py`` when building
the plain-jnp variants of the models (CNN layers, reference forward).

Everything here is written with ordinary ``jax.numpy`` ops only — no
Pallas, no custom calls — so they lower to vanilla HLO on any backend.
"""

from __future__ import annotations

import jax.numpy as jnp


def activate(z: jnp.ndarray, activation: str) -> jnp.ndarray:
    """Apply a named activation function.

    The paper's networks use ``sigmoid`` (XOR / parity / NIST7x7 MLPs),
    ``relu`` (CNN conv stacks) and ``linear`` (final fully-connected
    layers, no softmax - section 3.6).  The remaining names mirror the
    Rust ``ModelSpec`` activation table (``tanh``, ``identity`` — an
    alias of ``linear`` — and row-wise, max-shifted ``softmax``) so a
    ``--model`` spec lowers to the same function the native executor
    runs.

    ``softmax`` normalizes over the **last axis** and therefore needs the
    whole output row; apply it outside any output-tiled kernel (see
    ``model.mlp_forward``).
    """
    if activation == "sigmoid":
        return 1.0 / (1.0 + jnp.exp(-z))
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation in ("linear", "identity"):
        return z
    if activation == "tanh":
        return jnp.tanh(z)
    if activation == "softmax":
        shifted = z - jnp.max(z, axis=-1, keepdims=True)
        e = jnp.exp(shifted)
        return e / jnp.sum(e, axis=-1, keepdims=True)
    raise ValueError(f"unknown activation: {activation!r}")


def dense_forward_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    w_tilde: jnp.ndarray,
    b_tilde: jnp.ndarray,
    activation: str = "sigmoid",
) -> jnp.ndarray:
    """Perturbed dense layer: ``act(x @ (w + w_tilde) + (b + b_tilde))``.

    This is the MGD inference primitive: the perturbation ``theta_tilde``
    rides on top of the base value ``theta`` exactly as in Fig. 1(a,
    inset) of the paper.

    Args:
        x: ``[B, N]`` input activations.
        w: ``[N, M]`` base weights.
        b: ``[M]`` base biases.
        w_tilde: ``[N, M]`` weight perturbations (zero when unperturbed).
        b_tilde: ``[M]`` bias perturbations.
        activation: activation name, see :func:`activate`.

    Returns:
        ``[B, M]`` layer output.
    """
    z = x @ (w + w_tilde) + (b + b_tilde)
    return activate(z, activation)


def homodyne_accumulate_ref(
    g: jnp.ndarray,
    c_tilde: jnp.ndarray,
    theta_tilde: jnp.ndarray,
    delta_theta,
) -> jnp.ndarray:
    """Homodyne gradient accumulation: ``G <- G + C_tilde * theta_tilde / dtheta^2``.

    The per-parameter "local circuit" of Fig. 1(b): each parameter
    multiplies the globally-broadcast cost modulation ``C_tilde`` (a
    scalar) with its own local perturbation ``theta_tilde_i`` and
    integrates.  Paper Eq. (3) / Algorithm 1 lines 13-14.

    Args:
        g: ``[P]`` running gradient approximation.
        c_tilde: scalar cost modulation ``C - C0``.
        theta_tilde: ``[P]`` per-parameter perturbations this step.
        delta_theta: perturbation amplitude (normalization).

    Returns:
        ``[P]`` updated gradient approximation.
    """
    return g + c_tilde * theta_tilde / (delta_theta * delta_theta)


def mse_cost_ref(y: jnp.ndarray, y_hat: jnp.ndarray) -> jnp.ndarray:
    """Mean-squared-error cost, averaged over batch and outputs.

    Both MGD and the backprop baseline use plain MSE (section 3.6: "Both
    strategies used a mean squared error (MSE) cost function").
    """
    return jnp.mean((y - y_hat) ** 2)
