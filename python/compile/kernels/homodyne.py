"""L1 Pallas kernel: homodyne gradient accumulation.

Computes ``G <- G + C_tilde * theta_tilde / dtheta^2`` — paper Eq. (3) /
Algorithm 1 lines 13-14.  This is the per-parameter "local circuit" of
Fig. 1(b): every parameter multiplies the globally-broadcast scalar cost
modulation with its own local perturbation and integrates.

TPU mapping (DESIGN.md §Hardware-Adaptation): a pure VPU elementwise FMA
streamed over the parameter vector in 1-D tiles.  The broadcast scalar
``C_tilde`` is the literal hardware broadcast of the paper — here it is a
``(1,)`` operand replicated to every grid instance, i.e. each tile
"receives the broadcast" rather than re-deriving it.  On the fused
on-chip artifact (``mgd_scan``) this kernel runs once per timestep inside
the ``lax.scan`` body, so it is on the true hot path of training.

Lowered with ``interpret=True`` for CPU-PJRT portability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D tile edge.  8 * 128 lanes is the natural f32 VPU tile; parameters
# are a flat vector so we stream it in 1024-float chunks (shrunk to the
# largest divisor for exact grid coverage).
_TARGET_BLOCK_P = 1024


def _largest_divisor_at_most(n: int, cap: int) -> int:
    d = min(n, cap)
    while n % d != 0:
        d -= 1
    return d


def _homodyne_kernel(g_ref, ct_ref, tt_ref, inv2_ref, o_ref):
    """One 1-D parameter tile: ``o = g + ct * tt * inv_dtheta_sq``."""
    ct = ct_ref[0]          # broadcast scalar (cost modulation)
    inv2 = inv2_ref[0]      # precomputed 1/dtheta^2
    o_ref[...] = g_ref[...] + ct * tt_ref[...] * inv2


def homodyne_accumulate(
    g: jnp.ndarray,
    c_tilde: jnp.ndarray,
    theta_tilde: jnp.ndarray,
    delta_theta,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """Accumulate the instantaneous error signal into ``G`` via Pallas.

    Semantics identical to
    :func:`compile.kernels.ref.homodyne_accumulate_ref`.

    Args:
        g: ``[P]`` running gradient approximation.
        c_tilde: scalar (or 0-d array) cost modulation ``C - C0``.
        theta_tilde: ``[P]`` perturbation vector this step.
        delta_theta: scalar perturbation amplitude.
        interpret: keep True for CPU-PJRT-portable lowering.

    Returns:
        ``[P]`` updated gradient approximation.
    """
    (p,) = g.shape
    if theta_tilde.shape != (p,):
        raise ValueError(f"theta_tilde shape {theta_tilde.shape} != ({p},)")

    bp = _largest_divisor_at_most(p, _TARGET_BLOCK_P)
    grid = (p // bp,)

    ct = jnp.reshape(jnp.asarray(c_tilde, jnp.float32), (1,))
    dth = jnp.asarray(delta_theta, jnp.float32)
    inv2 = jnp.reshape(1.0 / (dth * dth), (1,))

    return pl.pallas_call(
        _homodyne_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),   # g tile
            pl.BlockSpec((1,), lambda i: (0,)),    # broadcast c_tilde
            pl.BlockSpec((bp,), lambda i: (i,)),   # theta_tilde tile
            pl.BlockSpec((1,), lambda i: (0,)),    # broadcast 1/dtheta^2
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=interpret,
    )(g, ct, theta_tilde, inv2)


def vmem_footprint_bytes(p: int) -> int:
    """Per-instance VMEM footprint estimate (bytes): g + tt + out tiles."""
    bp = _largest_divisor_at_most(p, _TARGET_BLOCK_P)
    return 4 * (3 * bp + 2)
