"""L2: the paper's networks as JAX compute graphs over a flat parameter vector.

Every network in the paper's evaluation (§3) is defined here:

=============  =======================================  ========  =========
model id       architecture                              params    paper use
=============  =======================================  ========  =========
``xor221``     2-2-1 sigmoid MLP                               9  Figs 4,6,7,9; Table 2
``parity441``  4-4-1 sigmoid MLP                              25  Fig 5
``nist744``    49-4-4 sigmoid MLP                            220  Figs 5,8,10; Table 2
``fmnist_cnn`` conv16-pool-conv32-pool-GAP-FC10             5130  Table 2 (Fashion-MNIST rows)
``cifar_cnn``  conv16/32/64-pool x3-pool-FC(256,10)        26154  Table 2 (CIFAR-10 row)
=============  =======================================  ========  =========

``cifar_cnn`` matches the paper's §3.6 description exactly (3x3 convs with
16/32/64 output channels, each followed by 2x2 maxpool, final 256 features
into a 10-way linear layer, no softmax) and reproduces the stated 26,154
parameter count.  The paper's Fashion-MNIST architecture description ("two
conv+maxpool layers, (32x10) fully-connected") is not consistent with its
stated 14,378 parameter count for any integer channel width; we implement
the description (16/32 channels, global-average-pool to 32 features) and
document the 5,130-parameter discrepancy in EXPERIMENTS.md.

All models take their parameters as a single flat ``f32[P]`` vector — the
"hardware parameter bus".  The flattening order is fixed and exported in
``artifacts/manifest.json`` so the Rust coordinator can initialize, perturb
and update parameters without any Python at runtime.

The MLP forward pass calls the L1 Pallas kernel
(:func:`compile.kernels.dense.dense_forward`); the backprop baseline
(`grad` artifacts) uses the mathematically-identical pure-jnp reference
path because interpret-mode Pallas does not support reverse-mode AD — the
two paths are cross-checked by ``python/tests/test_models.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import dense, homodyne, ref

# ---------------------------------------------------------------------------
# Model specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One tensor inside the flat parameter vector."""

    name: str
    shape: tuple[int, ...]
    init: str  # "uniform_pm1" | "xavier_uniform" | "zeros"

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    """Fully-connected network (paper's XOR / parity / NIST7x7 nets).

    ``activation`` broadcasts to every layer (the paper's all-sigmoid
    shape); ``activations`` — when non-empty — gives one name per weight
    layer and takes precedence, mirroring the Rust ``ModelSpec`` grammar
    (``784x128x64x10:relu,relu,softmax``).
    """

    name: str
    layers: tuple[int, ...]  # e.g. (49, 4, 4)
    activation: str = "sigmoid"
    activations: tuple[str, ...] = ()

    def __post_init__(self):
        if self.activations and len(self.activations) != len(self.layers) - 1:
            raise ValueError(
                f"{self.name}: {len(self.activations)} activations for "
                f"{len(self.layers) - 1} layers"
            )

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.layers[0],)

    @property
    def n_outputs(self) -> int:
        return self.layers[-1]

    @property
    def layer_activations(self) -> tuple[str, ...]:
        """One activation name per weight layer (broadcast resolved)."""
        if self.activations:
            return self.activations
        return (self.activation,) * (len(self.layers) - 1)

    def tensors(self) -> list[TensorSpec]:
        specs = []
        for li, (n_in, n_out) in enumerate(zip(self.layers[:-1], self.layers[1:])):
            specs.append(TensorSpec(f"w{li}", (n_in, n_out), "uniform_pm1"))
            specs.append(TensorSpec(f"b{li}", (n_out,), "uniform_pm1"))
        return specs

    @property
    def param_count(self) -> int:
        return sum(t.size for t in self.tensors())


# Accepted activation spellings -> canonical token (the Rust
# ``Activation::as_str`` names, which the canonical artifact stem embeds).
_ACT_ALIASES = {
    "sigmoid": "sigmoid",
    "sig": "sigmoid",
    "relu": "relu",
    "tanh": "tanh",
    "identity": "identity",
    "id": "identity",
    "linear": "identity",
    "softmax": "softmax",
}


def canonical_stem(layers: tuple[int, ...], acts: tuple[str, ...]) -> str:
    """The canonical artifact stem for a dense stack:
    ``mlp_<widths 'x'-joined>_<acts '-'-joined>`` — byte-identical to the
    Rust side's ``ModelSpec::artifact_stem`` for the same spec, which is
    what lets ``PjrtDevice::for_spec`` fall back to a stem lookup."""
    return f"mlp_{'x'.join(str(w) for w in layers)}_{'-'.join(acts)}"


def parse_spec(text: str) -> MlpSpec:
    """Parse the ``--model`` spec grammar into an :class:`MlpSpec`.

    Same grammar as the Rust ``ModelSpec::from_str``:
    ``WxWx...W[:act,act,...]`` — widths input-first, one activation per
    weight layer (a single activation broadcasts; no suffix means all
    sigmoid).  The resulting spec is named by :func:`canonical_stem`.
    """
    widths_part, _, acts_part = text.partition(":")
    try:
        layers = tuple(int(w) for w in widths_part.split("x"))
    except ValueError as e:
        raise ValueError(f"bad layer width in model spec {text!r}: {e}") from None
    if len(layers) < 2 or any(w < 1 for w in layers):
        raise ValueError(f"invalid model spec {text!r}: need >= 2 positive widths")
    n_layers = len(layers) - 1
    if acts_part:
        try:
            acts = tuple(_ACT_ALIASES[a.strip()] for a in acts_part.split(","))
        except KeyError as e:
            raise ValueError(
                f"unknown activation {e.args[0]!r} in model spec {text!r} "
                f"(known: {sorted(set(_ACT_ALIASES))})"
            ) from None
        if len(acts) == 1:
            acts = acts * n_layers
        if len(acts) != n_layers:
            raise ValueError(
                f"model spec {text!r}: {len(acts)} activations for {n_layers} layers"
            )
    else:
        acts = ("sigmoid",) * n_layers
    return MlpSpec(canonical_stem(layers, acts), layers, activations=acts)


@dataclasses.dataclass(frozen=True)
class CnnSpec:
    """Conv stack + linear head (paper's Fashion-MNIST / CIFAR-10 nets).

    Every conv is 3x3, stride 1, SAME padding, relu, followed by a 2x2
    maxpool (stride 2).  ``extra_pool`` adds one final 2x2 maxpool before
    the flatten (the CIFAR net needs it to reach the paper's 256 features).
    ``global_avg_pool`` collapses the spatial dims instead of flattening
    (the Fashion net's "(32x10) fully-connected layer").
    """

    name: str
    input_hw: tuple[int, int]
    input_channels: int
    conv_channels: tuple[int, ...]
    n_classes: int
    extra_pool: bool = False
    global_avg_pool: bool = False

    @property
    def input_shape(self) -> tuple[int, ...]:
        return (self.input_hw[0], self.input_hw[1], self.input_channels)

    @property
    def n_outputs(self) -> int:
        return self.n_classes

    def _fc_in(self) -> int:
        h, w = self.input_hw
        for _ in self.conv_channels:
            h, w = h // 2, w // 2
        if self.extra_pool:
            h, w = h // 2, w // 2
        c = self.conv_channels[-1]
        return c if self.global_avg_pool else h * w * c

    def tensors(self) -> list[TensorSpec]:
        specs = []
        cin = self.input_channels
        for li, cout in enumerate(self.conv_channels):
            specs.append(TensorSpec(f"conv{li}_k", (3, 3, cin, cout), "xavier_uniform"))
            specs.append(TensorSpec(f"conv{li}_b", (cout,), "zeros"))
            cin = cout
        specs.append(TensorSpec("fc_w", (self._fc_in(), self.n_classes), "xavier_uniform"))
        specs.append(TensorSpec("fc_b", (self.n_classes,), "zeros"))
        return specs

    @property
    def param_count(self) -> int:
        return sum(t.size for t in self.tensors())


MODELS: dict[str, MlpSpec | CnnSpec] = {
    "xor221": MlpSpec("xor221", (2, 2, 1)),
    "parity441": MlpSpec("parity441", (4, 4, 1)),
    "nist744": MlpSpec("nist744", (49, 4, 4)),
    "fmnist_cnn": CnnSpec(
        "fmnist_cnn",
        input_hw=(28, 28),
        input_channels=1,
        conv_channels=(16, 32),
        n_classes=10,
        global_avg_pool=True,
    ),
    "cifar_cnn": CnnSpec(
        "cifar_cnn",
        input_hw=(32, 32),
        input_channels=3,
        conv_channels=(16, 32, 64),
        n_classes=10,
        extra_pool=True,
    ),
}


# ---------------------------------------------------------------------------
# Parameter (un)flattening
# ---------------------------------------------------------------------------


def unflatten(spec: MlpSpec | CnnSpec, theta: jnp.ndarray) -> list[jnp.ndarray]:
    """Split the flat ``f32[P]`` parameter bus into the spec's tensors."""
    tensors = []
    offset = 0
    for ts in spec.tensors():
        tensors.append(theta[offset : offset + ts.size].reshape(ts.shape))
        offset += ts.size
    if offset != theta.shape[0]:
        raise ValueError(f"{spec.name}: theta has {theta.shape[0]} params, spec needs {offset}")
    return tensors


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def mlp_forward(
    spec: MlpSpec,
    theta: jnp.ndarray,
    x: jnp.ndarray,
    theta_tilde: jnp.ndarray | None = None,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """MLP inference with an optional parameter perturbation riding on top.

    ``theta_tilde`` is the MGD perturbation vector (same layout as
    ``theta``); passing ``None`` runs the unperturbed baseline (C0
    measurement).  ``use_pallas=True`` routes the dense layers through the
    L1 Pallas kernel; ``False`` uses the jnp oracle (needed for ``grad``).
    """
    tensors = unflatten(spec, theta)
    tilde = (
        unflatten(spec, theta_tilde)
        if theta_tilde is not None
        else [jnp.zeros(ts.shape, jnp.float32) for ts in spec.tensors()]
    )
    h = x
    n_layers = len(spec.layers) - 1
    acts = spec.layer_activations
    for li in range(n_layers):
        w, b = tensors[2 * li], tensors[2 * li + 1]
        wt, bt = tilde[2 * li], tilde[2 * li + 1]
        act = acts[li]
        # Softmax normalizes over the whole output row, so it cannot run
        # inside the output-tiled Pallas kernel: compute the linear part
        # in the kernel, normalize outside (the reference path matches).
        tile_act = "linear" if act == "softmax" else act
        if use_pallas:
            h = dense.dense_forward(h, w, b, wt, bt, tile_act)
        else:
            h = ref.dense_forward_ref(h, w, b, wt, bt, tile_act)
        if act == "softmax":
            h = ref.activate(h, "softmax")
    return h


def _maxpool2(h: jnp.ndarray) -> jnp.ndarray:
    """2x2 maxpool, stride 2, NHWC."""
    return lax.reduce_window(
        h, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def cnn_forward(
    spec: CnnSpec,
    theta: jnp.ndarray,
    x: jnp.ndarray,
    theta_tilde: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """CNN inference (NHWC), perturbation fused into the parameters.

    Convs stay in plain ``lax.conv_general_dilated`` (XLA already emits
    near-optimal CPU code for them); the MGD perturbation is a single
    vector add on the parameter bus before unflattening, which is exactly
    how the hardware applies it (§4.1: a perturbation element in series
    with the parameter).
    """
    eff = theta if theta_tilde is None else theta + theta_tilde
    tensors = unflatten(spec, eff)
    h = x
    for li in range(len(spec.conv_channels)):
        k, b = tensors[2 * li], tensors[2 * li + 1]
        h = lax.conv_general_dilated(
            h, k, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        h = jnp.maximum(h + b, 0.0)
        h = _maxpool2(h)
    if spec.extra_pool:
        h = _maxpool2(h)
    if spec.global_avg_pool:
        h = jnp.mean(h, axis=(1, 2))
    else:
        h = h.reshape(h.shape[0], -1)
    fc_w, fc_b = tensors[-2], tensors[-1]
    return h @ fc_w + fc_b


def forward(
    spec: MlpSpec | CnnSpec,
    theta: jnp.ndarray,
    x: jnp.ndarray,
    theta_tilde: jnp.ndarray | None = None,
    *,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Dispatch to the right forward pass for ``spec``."""
    if isinstance(spec, MlpSpec):
        return mlp_forward(spec, theta, x, theta_tilde, use_pallas=use_pallas)
    return cnn_forward(spec, theta, x, theta_tilde)


# ---------------------------------------------------------------------------
# Cost / eval / grad heads (the AOT artifact bodies)
# ---------------------------------------------------------------------------


def make_cost_fn(spec: MlpSpec | CnnSpec, *, use_pallas: bool = True) -> Callable:
    """``(theta[P], theta_tilde[P], x[B,...], y_hat[B,K]) -> (C,)``.

    The device-side cost evaluation the MGD coordinator calls on the hot
    path: one perturbed inference plus the MSE cost head.  Passing an
    all-zeros ``theta_tilde`` measures the baseline cost C0.
    """

    def cost_fn(theta, theta_tilde, x, y_hat):
        y = forward(spec, theta, x, theta_tilde, use_pallas=use_pallas)
        return (ref.mse_cost_ref(y, y_hat),)

    return cost_fn


def _correct_count(spec: MlpSpec | CnnSpec, y: jnp.ndarray, y_hat: jnp.ndarray) -> jnp.ndarray:
    """Number of correctly-classified samples in the batch (f32 scalar)."""
    if spec.n_outputs == 1:
        pred = y[:, 0] > 0.5
        want = y_hat[:, 0] > 0.5
        return jnp.sum((pred == want).astype(jnp.float32))
    pred = jnp.argmax(y, axis=-1)
    want = jnp.argmax(y_hat, axis=-1)
    return jnp.sum((pred == want).astype(jnp.float32))


def make_eval_fn(spec: MlpSpec | CnnSpec) -> Callable:
    """``(theta[P], x[B,...], y_hat[B,K]) -> (C, correct_count)``."""

    def eval_fn(theta, x, y_hat):
        y = forward(spec, theta, x, use_pallas=False)
        return ref.mse_cost_ref(y, y_hat), _correct_count(spec, y, y_hat)

    return eval_fn


def make_grad_fn(spec: MlpSpec | CnnSpec) -> Callable:
    """``(theta[P], x[B,...], y_hat[B,K]) -> (C, dC/dtheta[P])``.

    The paper's comparator (backprop + SGD, §3.6) and the "true gradient"
    for the Fig. 5 angle metric.  Uses the jnp reference forward
    (interpret-mode Pallas has no reverse-mode AD); equality of the two
    forwards is pytest-enforced.
    """

    def loss(theta, x, y_hat):
        y = forward(spec, theta, x, use_pallas=False)
        return ref.mse_cost_ref(y, y_hat)

    def grad_fn(theta, x, y_hat):
        c, g = jax.value_and_grad(loss)(theta, x, y_hat)
        return c, g

    return grad_fn


# ---------------------------------------------------------------------------
# Fused on-chip MGD scan (the performance path)
# ---------------------------------------------------------------------------


def make_mgd_scan_fn(
    spec: MlpSpec | CnnSpec,
    *,
    n_steps: int,
    use_pallas: bool = True,
) -> Callable:
    """Build the fused "on-chip autonomous training" artifact.

    Runs ``n_steps`` complete MGD timesteps (Algorithm 1 with
    ``tau_p = 1`` and random code — i.e. SPSA-style rademacher —
    perturbations) inside a single ``lax.scan``, so one PJRT call advances
    training by a whole window.  This models the paper's end-state
    deployment (§6: "local, autonomous circuits"), while the per-step
    ``cost`` artifact models chip-in-the-loop training.

    Runtime inputs (all supplied by the Rust coordinator)::

        theta      f32[P]      parameter bus
        g          f32[P]      gradient-integrator state (carried across calls)
        seed       u32[]       PRNG seed for this window's perturbations/noise
        eta        f32[]       learning rate
        dtheta     f32[]       perturbation amplitude
        sigma_c    f32[]       stddev of additive Gaussian cost noise (§3.5)
        sigma_th   f32[]       stddev of additive parameter-update noise (§3.5)
        tau_theta  i32[]       parameter-update period in steps (dynamic!)
        t0         i32[]       global step offset (keeps the tau_theta
                               phase continuous across windows)
        x_all      f32[N,...]  resident dataset inputs
        y_all      f32[N,K]    resident dataset targets
        idx        i32[T,B]    per-step sample schedule (encodes tau_x)

    Returns ``(theta', g', costs[T])`` where ``costs[t]`` is the perturbed
    cost observed at step ``t`` (the signal a hardware monitor would see).

    The per-step baseline cost C0 is re-measured every step; Algorithm 1
    caches it within a ``tau_x`` window, but re-measuring is arithmetically
    identical (theta is constant within a window) and keeps the scan body
    branch-free.  The chip-in-the-loop Rust path implements the cached
    variant literally.
    """
    p = spec.param_count

    def scan_fn(theta, g, seed, eta, dtheta, sigma_c, sigma_th, tau_theta, t0, x_all, y_all, idx):
        key = jax.random.PRNGKey(seed)
        k_pert, k_cost_noise, k_upd_noise = jax.random.split(key, 3)

        # Perf (EXPERIMENTS.md §Perf L2-1): generate the window's entire
        # randomness in three batched ops instead of per-step fold_in +
        # split + three draws — per-step threefry key scheduling dominated
        # the scan body for the small models.
        tt_all = dtheta * jax.random.rademacher(k_pert, (n_steps, p), jnp.float32)
        cn_all = sigma_c * jax.random.normal(k_cost_noise, (n_steps, 2))
        # Update noise is only consumed at update steps; skip generating
        # the (T, P) block entirely when sigma_th == 0 (the common case).
        un_all = lax.cond(
            sigma_th > 0.0,
            lambda: sigma_th * jax.random.normal(k_upd_noise, (n_steps, p)),
            lambda: jnp.zeros((n_steps, p), jnp.float32),
        )

        def cost_at(th, tt, xb, yb):
            y = forward(spec, th, xb, tt, use_pallas=use_pallas)
            return ref.mse_cost_ref(y, yb)

        def step(carry, t):
            theta, g = carry
            # Random code perturbation (statistically orthogonal, §3.4).
            tt = tt_all[t]
            xb = x_all[idx[t]]
            yb = y_all[idx[t]]
            # Baseline + perturbed cost, each with additive readout noise.
            c0 = cost_at(theta, None, xb, yb) + cn_all[t, 0]
            c = cost_at(theta, tt, xb, yb) + cn_all[t, 1]
            c_tilde = c - c0
            # Homodyne integration (L1 Pallas kernel on the hot path).
            if use_pallas:
                g = homodyne.homodyne_accumulate(g, c_tilde, tt, dtheta)
            else:
                g = ref.homodyne_accumulate_ref(g, c_tilde, tt, dtheta)
            # Parameter update every tau_theta steps (Eq. 4 + update noise).
            upd = ((t0 + t + 1) % tau_theta) == 0
            theta = jnp.where(upd, theta - eta * g + un_all[t], theta)
            g = jnp.where(upd, jnp.zeros_like(g), g)
            return (theta, g), c

        # Perf note (EXPERIMENTS.md §Perf L2-2): scan `unroll=4` was tried
        # and gained ~13% under the jax 0.8 runtime but *regressed* 15-60%
        # under the deployment runtime (xla_extension 0.5.1), so it is
        # intentionally not applied — always measure on the target runtime.
        (theta, g), costs = lax.scan(step, (theta, g), jnp.arange(n_steps))
        return theta, g, costs

    return scan_fn
